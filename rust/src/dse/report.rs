//! Sweep reporting: Pareto annotation, JSON / CSV export, and the ASCII
//! summary tables printed by the `hcim dse` subcommand.
//!
//! Pareto membership is computed **per workload** over the minimization
//! objectives — (energy, latency, area), extended by the Monte Carlo
//! flip-rate objective when the sweep ran with robustness enabled.
//! Comparing a ResNet-20 point against a VGG-11 point would be
//! meaningless.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::dse::pareto::pareto_flags_nd;
use crate::dse::runner::{PointResult, SweepResult};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

/// One reported row: a priced point plus its frontier flag.
#[derive(Clone, Debug)]
pub struct ReportRow {
    pub result: PointResult,
    pub pareto: bool,
}

/// A fully annotated sweep report.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub rows: Vec<ReportRow>,
    /// Per-workload indices (into `rows`) of the Pareto frontier.
    pub frontier: BTreeMap<String, Vec<usize>>,
    pub simulated: usize,
    pub cache_hits: usize,
}

impl SweepReport {
    /// Annotate a sweep result with per-workload Pareto membership.
    pub fn build(result: &SweepResult) -> SweepReport {
        let mut rows: Vec<ReportRow> = result
            .points
            .iter()
            .map(|p| ReportRow { result: p.clone(), pareto: false })
            .collect();

        // group row indices by workload, preserving order
        let mut by_workload: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, row) in rows.iter().enumerate() {
            by_workload
                .entry(row.result.point.workload.clone())
                .or_default()
                .push(i);
        }

        let mut frontier = BTreeMap::new();
        for (workload, indices) in &by_workload {
            // 3-objective, or 4 when the sweep measured robustness
            let objs: Vec<Vec<f64>> = indices
                .iter()
                .map(|&i| rows[i].result.metrics.objectives_nd())
                .collect();
            let flags = pareto_flags_nd(&objs);
            let members: Vec<usize> = indices
                .iter()
                .zip(&flags)
                .filter(|(_, &f)| f)
                .map(|(&i, _)| i)
                .collect();
            for &i in &members {
                rows[i].pareto = true;
            }
            frontier.insert(workload.clone(), members);
        }

        SweepReport {
            rows,
            frontier,
            simulated: result.simulated,
            cache_hits: result.cache_hits,
        }
    }

    /// True when any row carries the robustness objective.
    fn has_robustness(&self) -> bool {
        self.rows.iter().any(|r| r.result.metrics.robustness.is_some())
    }

    fn fmt_robustness(m: &crate::dse::cache::PointMetrics) -> String {
        m.robustness.map(|r| format!("{r:.4}")).unwrap_or_default()
    }

    /// Full point listing. The "Flip rate" column appears only when the
    /// sweep measured robustness.
    pub fn points_table(&self) -> Table {
        let with_rob = self.has_robustness();
        let mut headers = vec![
            "Workload", "Architecture", "Crossbar", "Node", "Energy (µJ)",
            "Latency (µs)", "Area (mm²)", "EDAP", "img/s", "Peak util", "Peak mW",
        ];
        if with_rob {
            headers.push("Flip rate");
        }
        headers.push("Pareto");
        headers.push("Cached");
        let mut t = Table::new("DSE sweep — all design points", &headers);
        for row in &self.rows {
            let p = &row.result.point;
            let m = &row.result.metrics;
            let mut cells = vec![
                p.workload.clone(),
                p.arch.name().to_string(),
                format!("{}x{}", p.xbar.rows, p.xbar.cols),
                p.node_label(),
                fnum(m.energy_pj / 1e6),
                fnum(m.latency_ns / 1e3),
                format!("{:.4}", m.area_mm2),
                format!("{:.3e}", m.edap()),
                fnum(m.throughput_ips),
                format!("{:.2}", m.peak_util),
                fnum(m.peak_power_mw),
            ];
            if with_rob {
                cells.push(Self::fmt_robustness(m));
            }
            cells.push(if row.pareto { "*".into() } else { "".into() });
            cells.push(if row.result.cached { "hit".into() } else { "".into() });
            t.row(&cells);
        }
        t
    }

    /// Frontier-only listing (plus the flip-rate objective when measured).
    pub fn pareto_table(&self) -> Table {
        let with_rob = self.has_robustness();
        let title = if with_rob {
            "DSE sweep — Pareto frontier (energy, latency, area, flip rate minimized)"
        } else {
            "DSE sweep — Pareto frontier (energy, latency, area minimized)"
        };
        let mut headers = vec![
            "Workload", "Architecture", "Crossbar", "Node", "Energy (µJ)",
            "Latency (µs)", "Area (mm²)",
        ];
        if with_rob {
            headers.push("Flip rate");
        }
        let mut t = Table::new(title, &headers);
        for members in self.frontier.values() {
            for &i in members {
                let p = &self.rows[i].result.point;
                let m = &self.rows[i].result.metrics;
                let mut cells = vec![
                    p.workload.clone(),
                    p.arch.name().to_string(),
                    format!("{}x{}", p.xbar.rows, p.xbar.cols),
                    p.node_label(),
                    fnum(m.energy_pj / 1e6),
                    fnum(m.latency_ns / 1e3),
                    format!("{:.4}", m.area_mm2),
                ];
                if with_rob {
                    cells.push(Self::fmt_robustness(m));
                }
                t.row(&cells);
            }
        }
        t
    }

    /// JSON document (point list + per-workload frontier indices).
    ///
    /// Run provenance — which points were cache hits, how many were
    /// simulated fresh — is deliberately absent (it lives in the stdout
    /// summary and the journal): the written report must be byte-identical
    /// whether the sweep ran cold, warm, or resumed after a crash.
    /// Version 2 dropped the `cached`/`simulated`/`cache_hits` fields.
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                let p = &row.result.point;
                let m = &row.result.metrics;
                let mut o = BTreeMap::new();
                o.insert("workload".into(), Json::Str(p.workload.clone()));
                o.insert("arch".into(), Json::Str(p.arch.name().to_string()));
                o.insert("arch_key".into(), Json::Str(p.arch.key().to_string()));
                o.insert("xbar_rows".into(), Json::Num(p.xbar.rows as f64));
                o.insert("xbar_cols".into(), Json::Num(p.xbar.cols as f64));
                o.insert("node".into(), Json::Str(p.node_label()));
                o.insert("energy_pj".into(), Json::Num(m.energy_pj));
                o.insert("latency_ns".into(), Json::Num(m.latency_ns));
                o.insert("area_mm2".into(), Json::Num(m.area_mm2));
                o.insert("edap".into(), Json::Num(m.edap()));
                o.insert("throughput_ips".into(), Json::Num(m.throughput_ips));
                o.insert("peak_util".into(), Json::Num(m.peak_util));
                o.insert("peak_power_mw".into(), Json::Num(m.peak_power_mw));
                if let Some(r) = m.robustness {
                    o.insert("robustness".into(), Json::Num(r));
                }
                o.insert("pareto".into(), Json::Bool(row.pareto));
                Json::Obj(o)
            })
            .collect();
        let frontier: BTreeMap<String, Json> = self
            .frontier
            .iter()
            .map(|(w, members)| {
                (
                    w.clone(),
                    Json::Arr(members.iter().map(|&i| Json::Num(i as f64)).collect()),
                )
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("version".into(), Json::Num(2.0));
        top.insert("points".into(), Json::Arr(points));
        top.insert("pareto".into(), Json::Obj(frontier));
        Json::Obj(top)
    }

    /// CSV export (one row per point; `robustness` empty when the sweep
    /// did not measure it). Like the JSON form, free of run provenance —
    /// no cached-vs-fresh column — so resumed runs emit identical bytes.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "workload,arch,xbar_rows,xbar_cols,node,energy_pj,latency_ns,area_mm2,edap,\
             throughput_ips,peak_util,peak_power_mw,robustness,pareto\n",
        );
        for row in &self.rows {
            let p = &row.result.point;
            let m = &row.result.metrics;
            out.push_str(&format!(
                "{},{},{},{},{},{:.6},{:.6},{:.8},{:.6e},{:.3},{:.6},{:.6},{},{}\n",
                p.workload,
                p.arch.key(),
                p.xbar.rows,
                p.xbar.cols,
                p.node_label(),
                m.energy_pj,
                m.latency_ns,
                m.area_mm2,
                m.edap(),
                m.throughput_ips,
                m.peak_util,
                m.peak_power_mw,
                m.robustness.map(|r| format!("{r:.6}")).unwrap_or_default(),
                row.pareto,
            ));
        }
        out
    }

    /// Write `sweep.json` and `sweep.csv` under `dir`; returns both paths.
    pub fn write(&self, dir: &Path) -> crate::Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        let json_path = dir.join("sweep.json");
        let csv_path = dir.join("sweep.csv");
        std::fs::write(&json_path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", json_path.display()))?;
        std::fs::write(&csv_path, self.to_csv())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", csv_path.display()))?;
        Ok((json_path, csv_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::CrossbarDims;
    use crate::dse::cache::PointMetrics;
    use crate::dse::space::{ArchKind, DesignPoint};
    use crate::sim::tech::TechNode;

    fn mk_point(arch: ArchKind, e: f64, l: f64, a: f64, rob: Option<f64>) -> PointResult {
        PointResult {
            point: DesignPoint {
                workload: "resnet20".into(),
                xbar: CrossbarDims { rows: 128, cols: 128 },
                node: TechNode::N32,
                arch,
            },
            metrics: PointMetrics {
                energy_pj: e,
                latency_ns: l,
                area_mm2: a,
                throughput_ips: 1000.0 / l,
                peak_util: 0.8,
                peak_power_mw: e / l,
                robustness: rob,
            },
            cached: false,
        }
    }

    fn synthetic_result() -> SweepResult {
        SweepResult {
            points: vec![
                mk_point(ArchKind::HcimTernary, 1.0, 2.0, 3.0, None), // frontier
                mk_point(ArchKind::AdcSar7, 5.0, 1.0, 3.0, None),     // frontier (faster)
                mk_point(ArchKind::AdcSar6, 6.0, 2.0, 4.0, None),     // dominated by both
            ],
            simulated: 3,
            cache_hits: 0,
        }
    }

    #[test]
    fn frontier_annotation() {
        let report = SweepReport::build(&synthetic_result());
        let flags: Vec<bool> = report.rows.iter().map(|r| r.pareto).collect();
        assert_eq!(flags, vec![true, true, false]);
        assert_eq!(report.frontier["resnet20"], vec![0, 1]);
    }

    #[test]
    fn robustness_objective_reshapes_the_frontier() {
        // same (e, l, a) geometry as synthetic_result(), but the point
        // dominated in 3D is uniquely robust → it joins the 4D frontier
        let result = SweepResult {
            points: vec![
                mk_point(ArchKind::HcimTernary, 1.0, 2.0, 3.0, Some(0.05)),
                mk_point(ArchKind::AdcSar7, 5.0, 1.0, 3.0, Some(0.05)),
                mk_point(ArchKind::AdcSar6, 6.0, 2.0, 4.0, Some(0.001)),
            ],
            simulated: 3,
            cache_hits: 0,
        };
        let report = SweepReport::build(&result);
        let flags: Vec<bool> = report.rows.iter().map(|r| r.pareto).collect();
        assert_eq!(flags, vec![true, true, true]);
        assert_eq!(report.frontier["resnet20"], vec![0, 1, 2]);
        // the robustness value flows into JSON and CSV
        let json = Json::parse(&report.to_json().to_string()).unwrap();
        let pts = json.get("points").unwrap().as_arr().unwrap();
        assert!((pts[2].num_field("robustness").unwrap() - 0.001).abs() < 1e-12);
        let csv = report.to_csv();
        assert!(csv.lines().nth(1).unwrap().contains(",0.050000,"));
        // and the frontier table advertises the fourth objective
        assert!(report.pareto_table().render().contains("flip rate minimized"));
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let report = SweepReport::build(&synthetic_result());
        let text = report.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        let points = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 3);
        assert_eq!(points[0].str_field("arch_key").unwrap(), "hcim-ternary");
        assert_eq!(points[0].get("pareto"), Some(&Json::Bool(true)));
        assert_eq!(points[2].get("pareto"), Some(&Json::Bool(false)));
        let frontier = parsed.get("pareto").unwrap().get("resnet20").unwrap();
        assert_eq!(frontier.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn csv_has_header_plus_rows() {
        let report = SweepReport::build(&synthetic_result());
        let csv = report.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("workload,arch"));
        assert!(lines[1].contains("hcim-ternary"));
        assert!(lines[1].ends_with(",true"));
        assert!(lines[3].ends_with(",false"));
    }

    #[test]
    fn written_artifacts_carry_no_run_provenance() {
        // the byte-identity contract for resumed sweeps: cached-vs-fresh
        // and hit counts must never reach sweep.json / sweep.csv
        let mut warm = synthetic_result();
        warm.points[0].cached = true;
        warm.simulated = 1;
        warm.cache_hits = 2;
        let cold_report = SweepReport::build(&synthetic_result());
        let warm_report = SweepReport::build(&warm);
        assert_eq!(
            cold_report.to_json().to_string(),
            warm_report.to_json().to_string()
        );
        assert_eq!(cold_report.to_csv(), warm_report.to_csv());
        let json = cold_report.to_json().to_string();
        assert!(!json.contains("cached"), "{json}");
        assert!(!json.contains("simulated"), "{json}");
    }

    #[test]
    fn tables_render() {
        let report = SweepReport::build(&synthetic_result());
        let all = report.points_table().render();
        assert!(all.contains("HCiM (Ternary)"));
        assert!(all.contains("*"));
        let front = report.pareto_table().render();
        assert!(front.contains("Pareto frontier"));
        assert!(!front.contains("ADC-6b"), "dominated point must not appear");
    }

    #[test]
    fn write_emits_both_files() {
        let dir = std::env::temp_dir().join("hcim_dse_report_write");
        let _ = std::fs::remove_dir_all(&dir);
        let report = SweepReport::build(&synthetic_result());
        let (j, c) = report.write(&dir).unwrap();
        assert!(j.exists());
        assert!(c.exists());
        let body = std::fs::read_to_string(j).unwrap();
        assert!(Json::parse(&body).is_ok());
    }
}
