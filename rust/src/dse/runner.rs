//! Parallel sweep execution.
//!
//! [`SweepRunner`] expands a [`DesignSpace`], answers what it can from the
//! [`ResultCache`], and prices the remaining points on the
//! [`crate::util::threadpool::ThreadPool`] — one independent
//! [`crate::sim::simulator::Simulator`] run per point, so the sweep scales
//! with cores. Results come back in enumeration order regardless of worker
//! scheduling, which makes whole-sweep output deterministic.

use std::sync::Arc;
use std::time::Instant;

use crate::dse::cache::{PointMetrics, ResultCache, CACHE_SCHEMA};
use crate::dse::space::{DesignPoint, DesignSpace};
use crate::journal::{self, TrialRecord, TrialStatus};
use crate::model::zoo;
use crate::nonideal::{run_monte_carlo, MonteCarloCfg, NonIdealityParams};
use crate::obs::{self, instrument, Progress};
use crate::sim::simulator::{Simulator, SparsityTable};
use crate::timeline::{self, TimelineCfg, TimelineModel};
use crate::util::threadpool::ThreadPool;

/// Reference batch size for the timeline throughput/utilization columns
/// every design point carries (images scheduled concurrently by the
/// discrete-event engine when pricing the point's real-world throughput).
pub const TIMELINE_BATCH: usize = 4;

/// Configuration of the optional robustness objective: when attached to a
/// [`SweepRunner`], every design point additionally runs a small Monte
/// Carlo ([`crate::nonideal`]) under its node's default non-ideality
/// magnitudes, and the mean PSQ-code flip rate joins (energy, latency,
/// area) as a fourth minimized Pareto objective. The same master seed is
/// used for every point, so points are compared under paired noise.
///
/// Periphery awareness is first-order: all archs share the analog
/// crossbar effects (conductance variation, stuck-at faults, IR drop) and
/// the PSQ quantizer of the point's config, but the comparator
/// input-referred offset is applied only to comparator-bank archs
/// ([`crate::dse::space::ArchKind::has_comparator_bank`]) — an ADC
/// baseline's own quantization behaviour is part of its ideal model, not
/// a non-ideality.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RobustnessCfg {
    /// Monte Carlo trials per design point.
    pub trials: usize,
    /// Master seed shared by all points.
    pub seed: u64,
}

impl Default for RobustnessCfg {
    fn default() -> Self {
        RobustnessCfg { trials: 8, seed: 42 }
    }
}

/// One priced design point.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: DesignPoint,
    pub metrics: PointMetrics,
    /// True when the metrics came from the cache instead of a fresh run.
    pub cached: bool,
}

/// Output of one sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    /// All points in enumeration order.
    pub points: Vec<PointResult>,
    /// Points simulated fresh in this run.
    pub simulated: usize,
    /// Points answered from the cache.
    pub cache_hits: usize,
}

/// Configurable sweep driver.
pub struct SweepRunner {
    space: DesignSpace,
    sparsity: SparsityTable,
    workers: usize,
    cache: ResultCache,
    robustness: Option<RobustnessCfg>,
}

impl SweepRunner {
    /// Runner over `space` with paper-default sparsity, an in-memory cache,
    /// and one worker per core.
    pub fn new(space: DesignSpace) -> SweepRunner {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        SweepRunner {
            space,
            sparsity: SparsityTable::paper_default(),
            workers: workers.max(1),
            cache: ResultCache::in_memory(),
            robustness: None,
        }
    }

    /// Attach the robustness objective: every point gains a Monte Carlo
    /// mean flip rate and the Pareto frontier becomes 4-objective.
    pub fn with_robustness(mut self, cfg: RobustnessCfg) -> SweepRunner {
        self.robustness = Some(cfg);
        self
    }

    /// Use measured sparsity (changes the cache key fingerprint).
    pub fn with_sparsity(mut self, table: SparsityTable) -> SweepRunner {
        self.sparsity = table;
        self
    }

    /// Worker-thread count (0 = auto).
    pub fn with_workers(mut self, n: usize) -> SweepRunner {
        if n > 0 {
            self.workers = n;
        }
        self
    }

    /// Attach a result cache (e.g. [`ResultCache::at_path`]).
    pub fn with_cache(mut self, cache: ResultCache) -> SweepRunner {
        self.cache = cache;
        self
    }

    /// Cache key of one point under the current sparsity table (and, when
    /// enabled, the robustness configuration — a plain sweep and a
    /// robustness sweep must not share entries).
    fn cache_key(&self, point: &DesignPoint) -> String {
        let mut key =
            format!("{CACHE_SCHEMA}|{}|sp{:016x}", point.key(), self.sparsity.fingerprint());
        if let Some(r) = self.robustness {
            key.push_str(&format!(
                "|{}t{}s{:016x}",
                crate::nonideal::MODEL_VERSION,
                r.trials,
                r.seed
            ));
        }
        key
    }

    /// Run the sweep: validate, split cached/uncached, simulate the
    /// uncached points in parallel, merge in enumeration order, and
    /// persist the cache.
    pub fn run(&mut self) -> crate::Result<SweepResult> {
        let _span = obs::wall_span("dse.sweep");
        self.space.validate()?;
        let points = self.space.enumerate();

        // Partition against the cache, remembering each point's slot (and
        // its precomputed key) so fresh results can be scattered back into
        // enumeration order.
        let mut results: Vec<Option<PointResult>> = vec![None; points.len()];
        let mut pending: Vec<(usize, DesignPoint, String)> = Vec::new();
        for (i, p) in points.into_iter().enumerate() {
            let key = self.cache_key(&p);
            match self.cache.lookup(&key) {
                // accept a hit only when its objective arity matches the
                // sweep's: a hand-edited cache file can strip a robustness
                // value (or graft one onto a plain entry), and mixed
                // 3/4-objective rows would corrupt the Pareto extraction —
                // re-simulate such entries instead
                Some(metrics) if metrics.robustness.is_some() == self.robustness.is_some() => {
                    results[i] = Some(PointResult { point: p, metrics, cached: true })
                }
                _ => pending.push((i, p, key)),
            }
        }
        let cache_hits = results.iter().filter(|r| r.is_some()).count();
        let simulated = pending.len();
        let inst = instrument::global();
        inst.counter("dse.cache.hit").add(cache_hits as u64);
        inst.counter("dse.cache.miss").add(simulated as u64);

        if !pending.is_empty() {
            let table = Arc::new(self.sparsity.clone());
            let robustness = self.robustness;
            let fingerprint = self.sparsity.fingerprint();
            let seed = robustness.map(|r| r.seed).unwrap_or(0);
            let pool = ThreadPool::new(self.workers.min(pending.len()).max(1));
            // With a journal backend, the progress meter is owned by the
            // sink: it ticks when a trial record becomes durable, so what
            // the meter reports is exactly what a crash would preserve.
            let sink = self.cache.journal_sink(
                "dse",
                pending.len() as u64,
                Some(Progress::new("dse.points", pending.len() as u64)),
            )?;
            let progress = sink
                .is_none()
                .then(|| Arc::new(Progress::new("dse.points", pending.len() as u64)));
            let worker_sink = sink.clone();
            let fresh = pool.map(pending, move |(i, p, key)| {
                let before = instrument::global().counter_values();
                let t0 = Instant::now();
                let (metrics, makespan_ns) = simulate_point(&p, &table, robustness);
                if let Some(sink) = &worker_sink {
                    let rec = TrialRecord {
                        sweep: "dse".to_string(),
                        key: key.clone(),
                        fingerprint,
                        seed,
                        status: TrialStatus::Ok,
                        metrics: metrics.to_json(),
                        virt_ns: Some(makespan_ns),
                        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                        unix_ms: journal::now_unix_ms(),
                        instruments: journal::counter_delta(
                            &before,
                            &instrument::global().counter_values(),
                        ),
                    };
                    if let Err(e) = sink.append_trial(&rec) {
                        crate::log_warn!("journal append failed for {key}: {e}");
                    }
                } else if let Some(progress) = &progress {
                    progress.tick();
                }
                (i, p, key, metrics)
            });
            for (i, p, key, metrics) in fresh {
                self.cache.insert(&key, metrics);
                results[i] = Some(PointResult { point: p, metrics, cached: false });
            }
            if let Err(e) = self.cache.save() {
                crate::log_warn!("could not persist sweep cache: {e}");
            }
            if let Some(sink) = &sink {
                sink.finish();
            }
        }

        Ok(SweepResult {
            points: results.into_iter().map(|r| r.expect("all slots filled")).collect(),
            simulated,
            cache_hits,
        })
    }
}

/// Price one design point (runs on a worker thread). The workload was
/// validated by [`DesignSpace::validate`], so the zoo lookup cannot fail.
/// With `robustness` set, the point additionally runs a serial Monte Carlo
/// (serial because this function already executes inside a pool worker).
/// The Monte Carlo's trials × layers of crossbar MVMs run on the packed
/// [`crate::quant::psq::PsqEngine`] / [`crate::nonideal::NonIdealEngine`]
/// hot path — weight-stationary programming paid once per (layer, trial),
/// AND+popcount word kernels per stream — which is what keeps
/// `--robustness` sweeps tractable at DSE scale (EXPERIMENTS.md §Perf).
fn simulate_point(
    point: &DesignPoint,
    sparsity: &SparsityTable,
    robustness: Option<RobustnessCfg>,
) -> (PointMetrics, f64) {
    let graph = zoo::by_name(&point.workload).expect("workload validated before dispatch");
    let sim = Simulator::new(point.node).with_sparsity(sparsity.clone());
    let report = sim.run(&graph, &point.arch());
    // every point also runs the discrete-event timeline once (a few
    // hundred chunk tasks — negligible next to the analytic pricing) so
    // the sweep reports scheduled throughput and the bottleneck
    // component's utilization, not just the serial-latency abstraction
    let tl_model =
        TimelineModel::from_graph(&graph, &point.arch(), &sim.params, &sim.sparsity, None)
            .expect("unbudgeted timeline build cannot fail");
    // power on: the trace is cheap (a handful of windows per class) and
    // gives every point its delivery-envelope column, peak_power_mw
    let tl = timeline::simulate(
        &tl_model,
        &TimelineCfg { batch: TIMELINE_BATCH, chunks: 8, power: true, ..TimelineCfg::default() },
    );
    let robustness = robustness.map(|rc| {
        let cfg = point.arch().config().clone();
        let mut ni = NonIdealityParams::default_for(point.node);
        // the crossbar effects hit every analog periphery; the comparator
        // input-referred offset only exists where a comparator bank does
        if !point.arch.has_comparator_bank() {
            ni.sigma_cmp = 0.0;
        }
        let mc = MonteCarloCfg { trials: rc.trials.max(1), seed: rc.seed, workers: 1 };
        run_monte_carlo(&graph, &cfg, &ni, &mc).flip.mean
    });
    let metrics = PointMetrics {
        energy_pj: report.energy_pj(),
        latency_ns: report.latency_ns(),
        area_mm2: report.area_mm2(),
        throughput_ips: tl.throughput_ips,
        peak_util: tl.peak_util(),
        peak_power_mw: tl.power.as_ref().map(|p| p.peak_total_mw()).unwrap_or(0.0),
        robustness,
    };
    // the scheduled makespan doubles as the trial's virtual-time column
    // in journal records
    (metrics, tl.makespan_ns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::CrossbarDims;
    use crate::dse::space::ArchKind;
    use crate::sim::tech::TechNode;

    fn tiny_space() -> DesignSpace {
        DesignSpace::new()
            .with_workloads(&["resnet20"])
            .with_sizes(&[CrossbarDims { rows: 128, cols: 128 }])
            .with_nodes(&[TechNode::N32])
            .with_archs(&[ArchKind::HcimTernary, ArchKind::AdcFlash4])
    }

    #[test]
    fn runs_and_orders_points() {
        let r = SweepRunner::new(tiny_space()).with_workers(2).run().unwrap();
        assert_eq!(r.points.len(), 2);
        assert_eq!(r.simulated, 2);
        assert_eq!(r.cache_hits, 0);
        assert_eq!(r.points[0].point.arch, ArchKind::HcimTernary);
        assert_eq!(r.points[1].point.arch, ArchKind::AdcFlash4);
        for p in &r.points {
            assert!(!p.cached);
            assert!(p.metrics.energy_pj > 0.0);
            assert!(p.metrics.latency_ns > 0.0);
            assert!(p.metrics.area_mm2 > 0.0);
            assert!(p.metrics.throughput_ips > 0.0, "timeline throughput column missing");
            assert!(
                p.metrics.peak_util > 0.0 && p.metrics.peak_util <= 1.0 + 1e-9,
                "peak util {} out of range",
                p.metrics.peak_util
            );
            assert!(p.metrics.peak_power_mw > 0.0, "timeline power column missing");
        }
        // the ADC baseline costs more energy than ternary HCiM (Fig. 6)
        assert!(r.points[1].metrics.energy_pj > r.points[0].metrics.energy_pj);
    }

    #[test]
    fn matches_direct_simulator_run() {
        let r = SweepRunner::new(tiny_space()).run().unwrap();
        let direct = {
            let sim = Simulator::new(TechNode::N32);
            let g = zoo::resnet20();
            sim.run(&g, &r.points[0].point.arch())
        };
        assert!((r.points[0].metrics.energy_pj - direct.energy_pj()).abs() < 1e-6);
        assert!((r.points[0].metrics.latency_ns - direct.latency_ns()).abs() < 1e-6);
    }

    #[test]
    fn invalid_space_is_an_error() {
        let bad = tiny_space().with_workloads(&["not-a-model"]);
        assert!(SweepRunner::new(bad).run().is_err());
    }

    #[test]
    fn second_run_hits_file_cache() {
        let dir = std::env::temp_dir().join("hcim_dse_runner_cache");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");

        let first = SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(first.simulated, 2);

        let second = SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(second.simulated, 0, "everything should come from the cache");
        assert_eq!(second.cache_hits, 2);
        for (a, b) in first.points.iter().zip(&second.points) {
            assert_eq!(a.metrics, b.metrics);
            assert!(b.cached);
        }
    }

    #[test]
    fn robustness_objective_attaches_to_every_point() {
        let r = SweepRunner::new(tiny_space())
            .with_workers(2)
            .with_robustness(RobustnessCfg { trials: 2, seed: 7 })
            .run()
            .unwrap();
        for p in &r.points {
            let rob = p.metrics.robustness.expect("robustness must be measured");
            assert!((0.0..=1.0).contains(&rob), "flip rate {rob} out of range");
            assert_eq!(p.metrics.objectives_nd().len(), 4);
        }
        // plain sweeps stay 3-objective
        let plain = SweepRunner::new(tiny_space()).run().unwrap();
        assert!(plain.points.iter().all(|p| p.metrics.robustness.is_none()));
    }

    #[test]
    fn robustness_sweeps_do_not_share_cache_with_plain_sweeps() {
        let dir = std::env::temp_dir().join("hcim_dse_runner_rob_cache");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let plain = SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(plain.simulated, 2);
        // a robustness sweep must not reuse the 3-objective entries…
        let rob = SweepRunner::new(tiny_space())
            .with_robustness(RobustnessCfg { trials: 2, seed: 7 })
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(rob.simulated, 2, "plain entries must not satisfy a robustness sweep");
        // …but a repeated robustness sweep hits, robustness value intact
        let again = SweepRunner::new(tiny_space())
            .with_robustness(RobustnessCfg { trials: 2, seed: 7 })
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(again.cache_hits, 2);
        for (a, b) in rob.points.iter().zip(&again.points) {
            assert_eq!(a.metrics, b.metrics);
            assert!(b.metrics.robustness.is_some());
        }
    }

    #[test]
    fn stripped_robustness_entries_are_resimulated_not_mixed() {
        // a hand-edited cache file can drop robustness values while
        // keeping the robustness-flavoured keys; the runner must
        // re-simulate those entries rather than feed a 3-objective row
        // into a 4-objective Pareto extraction
        let dir = std::env::temp_dir().join("hcim_dse_runner_rob_strip");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let rob = RobustnessCfg { trials: 2, seed: 7 };
        let first = SweepRunner::new(tiny_space())
            .with_robustness(rob)
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(first.simulated, 2);

        // strip every `"robustness":<value>` field from the cache file
        let body = std::fs::read_to_string(&path).unwrap();
        let needle = ",\"robustness\":";
        let mut stripped = String::new();
        let mut rest = body.as_str();
        while let Some(i) = rest.find(needle) {
            stripped.push_str(&rest[..i]);
            let after = &rest[i + needle.len()..];
            let j = after.find('}').expect("entry object closes");
            rest = &after[j..];
        }
        stripped.push_str(rest);
        assert_ne!(body, stripped, "test must actually strip something");
        std::fs::write(&path, stripped).unwrap();

        let second = SweepRunner::new(tiny_space())
            .with_robustness(rob)
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(second.simulated, 2, "stripped entries must be re-simulated");
        assert_eq!(second.cache_hits, 0);
        assert!(second.points.iter().all(|p| p.metrics.robustness.is_some()));
    }

    #[test]
    fn plain_sweep_rejects_entries_grafted_with_robustness() {
        // the opposite corruption: a robustness value added to an entry a
        // plain sweep would hit must also force re-simulation, or the
        // plain sweep would mix 3- and 4-objective rows
        let dir = std::env::temp_dir().join("hcim_dse_runner_rob_graft");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        // graft a robustness field onto every cached entry
        let body = std::fs::read_to_string(&path).unwrap();
        let grafted = body.replace("\"energy_pj\":", "\"robustness\":0.01,\"energy_pj\":");
        assert_ne!(body, grafted);
        std::fs::write(&path, grafted).unwrap();

        let second = SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(second.simulated, 2, "grafted entries must be re-simulated");
        assert!(second.points.iter().all(|p| p.metrics.robustness.is_none()));
    }

    #[test]
    fn sparsity_change_invalidates_cache() {
        let dir = std::env::temp_dir().join("hcim_dse_runner_sparsity");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        let custom = {
            let j = crate::util::json::Json::parse(
                r#"{"resnet20": {"layers": [0.9,0.9,0.9,0.9,0.9,0.9,0.9,0.9,0.9,0.9]}}"#,
            )
            .unwrap();
            SparsityTable::from_json(&j).unwrap()
        };
        let second = SweepRunner::new(tiny_space())
            .with_sparsity(custom)
            .with_cache(ResultCache::at_path(&path).unwrap())
            .run()
            .unwrap();
        assert_eq!(second.simulated, 2, "different sparsity must not reuse entries");
    }
}
