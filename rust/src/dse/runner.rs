//! Parallel sweep execution.
//!
//! [`SweepRunner`] expands a [`DesignSpace`], answers what it can from the
//! [`ResultCache`], and prices the remaining points on the
//! [`crate::util::threadpool::ThreadPool`] — one independent
//! [`crate::sim::simulator::Simulator`] run per point, so the sweep scales
//! with cores. Results come back in enumeration order regardless of worker
//! scheduling, which makes whole-sweep output deterministic.

use std::sync::Arc;

use crate::dse::cache::{PointMetrics, ResultCache, CACHE_SCHEMA};
use crate::dse::space::{DesignPoint, DesignSpace};
use crate::model::zoo;
use crate::sim::simulator::{Simulator, SparsityTable};
use crate::util::threadpool::ThreadPool;

/// One priced design point.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: DesignPoint,
    pub metrics: PointMetrics,
    /// True when the metrics came from the cache instead of a fresh run.
    pub cached: bool,
}

/// Output of one sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    /// All points in enumeration order.
    pub points: Vec<PointResult>,
    /// Points simulated fresh in this run.
    pub simulated: usize,
    /// Points answered from the cache.
    pub cache_hits: usize,
}

/// Configurable sweep driver.
pub struct SweepRunner {
    space: DesignSpace,
    sparsity: SparsityTable,
    workers: usize,
    cache: ResultCache,
}

impl SweepRunner {
    /// Runner over `space` with paper-default sparsity, an in-memory cache,
    /// and one worker per core.
    pub fn new(space: DesignSpace) -> SweepRunner {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        SweepRunner {
            space,
            sparsity: SparsityTable::paper_default(),
            workers: workers.max(1),
            cache: ResultCache::in_memory(),
        }
    }

    /// Use measured sparsity (changes the cache key fingerprint).
    pub fn with_sparsity(mut self, table: SparsityTable) -> SweepRunner {
        self.sparsity = table;
        self
    }

    /// Worker-thread count (0 = auto).
    pub fn with_workers(mut self, n: usize) -> SweepRunner {
        if n > 0 {
            self.workers = n;
        }
        self
    }

    /// Attach a result cache (e.g. [`ResultCache::at_path`]).
    pub fn with_cache(mut self, cache: ResultCache) -> SweepRunner {
        self.cache = cache;
        self
    }

    /// Cache key of one point under the current sparsity table.
    fn cache_key(&self, point: &DesignPoint) -> String {
        format!("{CACHE_SCHEMA}|{}|sp{:016x}", point.key(), self.sparsity.fingerprint())
    }

    /// Run the sweep: validate, split cached/uncached, simulate the
    /// uncached points in parallel, merge in enumeration order, and
    /// persist the cache.
    pub fn run(&mut self) -> crate::Result<SweepResult> {
        self.space.validate()?;
        let points = self.space.enumerate();

        // Partition against the cache, remembering each point's slot so
        // fresh results can be scattered back into enumeration order.
        let mut results: Vec<Option<PointResult>> = vec![None; points.len()];
        let mut pending: Vec<(usize, DesignPoint)> = Vec::new();
        for (i, p) in points.into_iter().enumerate() {
            let key = self.cache_key(&p);
            match self.cache.lookup(&key) {
                Some(metrics) => {
                    results[i] = Some(PointResult { point: p, metrics, cached: true })
                }
                None => pending.push((i, p)),
            }
        }
        let cache_hits = results.iter().filter(|r| r.is_some()).count();
        let simulated = pending.len();

        if !pending.is_empty() {
            let table = Arc::new(self.sparsity.clone());
            let pool = ThreadPool::new(self.workers.min(pending.len()).max(1));
            let fresh = pool.map(pending, move |(i, p)| {
                let metrics = simulate_point(&p, &table);
                (i, p, metrics)
            });
            for (i, p, metrics) in fresh {
                let key = self.cache_key(&p);
                self.cache.insert(&key, metrics);
                results[i] = Some(PointResult { point: p, metrics, cached: false });
            }
            if let Err(e) = self.cache.save() {
                crate::log_warn!("could not persist sweep cache: {e}");
            }
        }

        Ok(SweepResult {
            points: results.into_iter().map(|r| r.expect("all slots filled")).collect(),
            simulated,
            cache_hits,
        })
    }
}

/// Price one design point (runs on a worker thread). The workload was
/// validated by [`DesignSpace::validate`], so the zoo lookup cannot fail.
fn simulate_point(point: &DesignPoint, sparsity: &SparsityTable) -> PointMetrics {
    let graph = zoo::by_name(&point.workload).expect("workload validated before dispatch");
    let sim = Simulator::new(point.node).with_sparsity(sparsity.clone());
    let report = sim.run(&graph, &point.arch());
    PointMetrics {
        energy_pj: report.energy_pj(),
        latency_ns: report.latency_ns(),
        area_mm2: report.area_mm2(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::CrossbarDims;
    use crate::dse::space::ArchKind;
    use crate::sim::tech::TechNode;

    fn tiny_space() -> DesignSpace {
        DesignSpace::new()
            .with_workloads(&["resnet20"])
            .with_sizes(&[CrossbarDims { rows: 128, cols: 128 }])
            .with_nodes(&[TechNode::N32])
            .with_archs(&[ArchKind::HcimTernary, ArchKind::AdcFlash4])
    }

    #[test]
    fn runs_and_orders_points() {
        let r = SweepRunner::new(tiny_space()).with_workers(2).run().unwrap();
        assert_eq!(r.points.len(), 2);
        assert_eq!(r.simulated, 2);
        assert_eq!(r.cache_hits, 0);
        assert_eq!(r.points[0].point.arch, ArchKind::HcimTernary);
        assert_eq!(r.points[1].point.arch, ArchKind::AdcFlash4);
        for p in &r.points {
            assert!(!p.cached);
            assert!(p.metrics.energy_pj > 0.0);
            assert!(p.metrics.latency_ns > 0.0);
            assert!(p.metrics.area_mm2 > 0.0);
        }
        // the ADC baseline costs more energy than ternary HCiM (Fig. 6)
        assert!(r.points[1].metrics.energy_pj > r.points[0].metrics.energy_pj);
    }

    #[test]
    fn matches_direct_simulator_run() {
        let r = SweepRunner::new(tiny_space()).run().unwrap();
        let direct = {
            let sim = Simulator::new(TechNode::N32);
            let g = zoo::resnet20();
            sim.run(&g, &r.points[0].point.arch())
        };
        assert!((r.points[0].metrics.energy_pj - direct.energy_pj()).abs() < 1e-6);
        assert!((r.points[0].metrics.latency_ns - direct.latency_ns()).abs() < 1e-6);
    }

    #[test]
    fn invalid_space_is_an_error() {
        let bad = tiny_space().with_workloads(&["not-a-model"]);
        assert!(SweepRunner::new(bad).run().is_err());
    }

    #[test]
    fn second_run_hits_file_cache() {
        let dir = std::env::temp_dir().join("hcim_dse_runner_cache");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");

        let first = SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path))
            .run()
            .unwrap();
        assert_eq!(first.simulated, 2);

        let second = SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path))
            .run()
            .unwrap();
        assert_eq!(second.simulated, 0, "everything should come from the cache");
        assert_eq!(second.cache_hits, 2);
        for (a, b) in first.points.iter().zip(&second.points) {
            assert_eq!(a.metrics, b.metrics);
            assert!(b.cached);
        }
    }

    #[test]
    fn sparsity_change_invalidates_cache() {
        let dir = std::env::temp_dir().join("hcim_dse_runner_sparsity");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        SweepRunner::new(tiny_space())
            .with_cache(ResultCache::at_path(&path))
            .run()
            .unwrap();
        let custom = {
            let j = crate::util::json::Json::parse(
                r#"{"resnet20": {"layers": [0.9,0.9,0.9,0.9,0.9,0.9,0.9,0.9,0.9,0.9]}}"#,
            )
            .unwrap();
            SparsityTable::from_json(&j).unwrap()
        };
        let second = SweepRunner::new(tiny_space())
            .with_sparsity(custom)
            .with_cache(ResultCache::at_path(&path))
            .run()
            .unwrap();
        assert_eq!(second.simulated, 2, "different sparsity must not reuse entries");
    }
}
