//! Declarative design-space description.
//!
//! A [`DesignSpace`] is a set of axes over the hardware/workload knobs the
//! simulator exposes: crossbar geometry, technology node, column-periphery
//! architecture, and workload (zoo model). [`DesignSpace::enumerate`]
//! expands the cartesian product into concrete [`DesignPoint`]s in a
//! deterministic order; each point knows how to build its [`HcimConfig`]
//! and [`Arch`] and carries a canonical string key used by the result
//! cache.

use crate::config::hardware::{BaselineKind, CrossbarDims, HcimConfig};
use crate::model::zoo;
use crate::sim::simulator::Arch;
use crate::sim::tech::TechNode;

/// Column-periphery architecture axis: the proposed design, its binary
/// variant, and every baseline the simulator models (§5.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArchKind {
    HcimTernary,
    HcimBinary,
    AdcSar7,
    AdcSar6,
    AdcFlash4,
    Quarry1,
    Quarry4,
    BitSplitNet,
}

impl ArchKind {
    pub const ALL: [ArchKind; 8] = [
        ArchKind::HcimTernary,
        ArchKind::HcimBinary,
        ArchKind::AdcSar7,
        ArchKind::AdcSar6,
        ArchKind::AdcFlash4,
        ArchKind::Quarry1,
        ArchKind::Quarry4,
        ArchKind::BitSplitNet,
    ];

    /// Short stable slug used in cache keys, CSV, and CLI arguments.
    pub fn key(self) -> &'static str {
        match self {
            ArchKind::HcimTernary => "hcim-ternary",
            ArchKind::HcimBinary => "hcim-binary",
            ArchKind::AdcSar7 => "adc7",
            ArchKind::AdcSar6 => "adc6",
            ArchKind::AdcFlash4 => "adc4",
            ArchKind::Quarry1 => "quarry1",
            ArchKind::Quarry4 => "quarry4",
            ArchKind::BitSplitNet => "bitsplit",
        }
    }

    /// Human label, matching the figure legends of the experiments module.
    pub fn name(self) -> &'static str {
        match self {
            ArchKind::HcimTernary => "HCiM (Ternary)",
            ArchKind::HcimBinary => "HCiM (Binary)",
            ArchKind::AdcSar7 => BaselineKind::AdcSar7.name(),
            ArchKind::AdcSar6 => BaselineKind::AdcSar6.name(),
            ArchKind::AdcFlash4 => BaselineKind::AdcFlash4.name(),
            ArchKind::Quarry1 => "Quarry (1-bit)",
            ArchKind::Quarry4 => "Quarry (4-bit)",
            ArchKind::BitSplitNet => "BitSplitNet",
        }
    }

    /// Parse a CLI slug.
    pub fn by_key(key: &str) -> Option<ArchKind> {
        ArchKind::ALL.iter().copied().find(|a| a.key() == key)
    }

    /// True when this periphery quantizes with HCiM's comparator bank —
    /// the archs subject to comparator input-referred offset. ADC-based
    /// peripheries (baselines, Quarry, BitSplitNet) share the analog
    /// crossbar effects (conductance variation, faults, IR drop) but have
    /// no comparator to offset.
    pub fn has_comparator_bank(self) -> bool {
        matches!(self, ArchKind::HcimTernary | ArchKind::HcimBinary)
    }

    /// The simulator architecture for this axis value on `cfg`.
    pub fn to_arch(self, cfg: HcimConfig) -> Arch {
        match self {
            ArchKind::HcimTernary => Arch::Hcim(cfg.ternary(4.0)),
            ArchKind::HcimBinary => Arch::Hcim(cfg.binary()),
            ArchKind::AdcSar7 => Arch::AdcBaseline(cfg, BaselineKind::AdcSar7),
            ArchKind::AdcSar6 => Arch::AdcBaseline(cfg, BaselineKind::AdcSar6),
            ArchKind::AdcFlash4 => Arch::AdcBaseline(cfg, BaselineKind::AdcFlash4),
            ArchKind::Quarry1 => Arch::Quarry(cfg, 1),
            ArchKind::Quarry4 => Arch::Quarry(cfg, 4),
            ArchKind::BitSplitNet => Arch::BitSplitNet(cfg),
        }
    }
}

/// One concrete point of the design space.
#[derive(Clone, Debug, PartialEq)]
pub struct DesignPoint {
    /// Zoo model name.
    pub workload: String,
    pub xbar: CrossbarDims,
    pub node: TechNode,
    pub arch: ArchKind,
}

impl DesignPoint {
    /// Canonical identity string (cache key component, stable across runs).
    pub fn key(&self) -> String {
        format!(
            "{}|{}x{}|{:.0}nm|{}",
            self.workload, self.xbar.rows, self.xbar.cols, self.node.nm,
            self.arch.key()
        )
    }

    /// Display label for the technology node.
    pub fn node_label(&self) -> String {
        format!("{:.0}nm", self.node.nm)
    }

    /// Hardware configuration of this point: the paper's base config for
    /// the workload family with the geometry/node axes applied.
    pub fn config(&self) -> HcimConfig {
        let mut cfg = if self.workload == "resnet18" {
            HcimConfig::imagenet()
        } else {
            HcimConfig::config_a()
        };
        cfg.xbar = self.xbar;
        cfg.node = self.node;
        cfg.name = format!("{}x{}", self.xbar.rows, self.xbar.cols);
        cfg
    }

    /// The simulator architecture for this point.
    pub fn arch(&self) -> Arch {
        self.arch.to_arch(self.config())
    }
}

/// Axes of a sweep. Build with the `with_*` methods; empty axes are
/// rejected at validation time.
#[derive(Clone, Debug, Default)]
pub struct DesignSpace {
    pub workloads: Vec<String>,
    pub xbar_sizes: Vec<CrossbarDims>,
    pub nodes: Vec<TechNode>,
    pub archs: Vec<ArchKind>,
}

impl DesignSpace {
    pub fn new() -> DesignSpace {
        DesignSpace::default()
    }

    pub fn with_workloads(mut self, names: &[&str]) -> DesignSpace {
        self.workloads = names.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn with_sizes(mut self, sizes: &[CrossbarDims]) -> DesignSpace {
        self.xbar_sizes = sizes.to_vec();
        self
    }

    pub fn with_nodes(mut self, nodes: &[TechNode]) -> DesignSpace {
        self.nodes = nodes.to_vec();
        self
    }

    pub fn with_archs(mut self, archs: &[ArchKind]) -> DesignSpace {
        self.archs = archs.to_vec();
        self
    }

    /// The default exploration space around the paper's operating points:
    /// config-A/B crossbar geometries × {32 nm, 65 nm} × six peripheries —
    /// 24 design points per workload.
    pub fn default_for(workloads: &[String]) -> DesignSpace {
        DesignSpace {
            workloads: workloads.to_vec(),
            xbar_sizes: vec![
                CrossbarDims { rows: 64, cols: 64 },
                CrossbarDims { rows: 128, cols: 128 },
            ],
            nodes: vec![TechNode::N32, TechNode::N65],
            archs: vec![
                ArchKind::HcimTernary,
                ArchKind::HcimBinary,
                ArchKind::AdcSar7,
                ArchKind::AdcSar6,
                ArchKind::AdcFlash4,
                ArchKind::Quarry1,
            ],
        }
    }

    /// Number of points the cartesian product will produce.
    pub fn len(&self) -> usize {
        self.workloads.len() * self.xbar_sizes.len() * self.nodes.len() * self.archs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Check the axes are usable before a sweep starts: non-empty, known
    /// workloads, and geometries the DCiM array model supports (≤128
    /// columns per array).
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(!self.is_empty(), "design space has an empty axis");
        for w in &self.workloads {
            anyhow::ensure!(zoo::by_name(w).is_some(), "unknown workload `{w}` in design space");
        }
        for s in &self.xbar_sizes {
            anyhow::ensure!(s.rows >= 1 && s.cols >= 1, "degenerate crossbar {}x{}", s.rows, s.cols);
            anyhow::ensure!(
                s.cols <= 128,
                "crossbar {}x{}: one DCiM array serves at most 128 columns",
                s.rows,
                s.cols
            );
        }
        Ok(())
    }

    /// Expand the cartesian product, deterministically ordered
    /// (workload-major, then geometry, node, arch).
    pub fn enumerate(&self) -> Vec<DesignPoint> {
        let mut points = Vec::with_capacity(self.len());
        for w in &self.workloads {
            for &xbar in &self.xbar_sizes {
                for &node in &self.nodes {
                    for &arch in &self.archs {
                        points.push(DesignPoint { workload: w.clone(), xbar, node, arch });
                    }
                }
            }
        }
        points
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn default_space_has_at_least_24_points() {
        let s = DesignSpace::default_for(&["resnet20".to_string()]);
        assert!(s.len() >= 24, "default space has {} points", s.len());
        assert!(s.validate().is_ok());
        assert_eq!(s.enumerate().len(), s.len());
    }

    #[test]
    fn enumeration_is_deterministic_with_unique_keys() {
        let s = DesignSpace::default_for(&["resnet20".to_string(), "vgg9".to_string()]);
        let a = s.enumerate();
        let b = s.enumerate();
        assert_eq!(a, b);
        let keys: BTreeSet<String> = a.iter().map(|p| p.key()).collect();
        assert_eq!(keys.len(), a.len(), "cache keys must be unique");
    }

    #[test]
    fn validate_rejects_bad_spaces() {
        assert!(DesignSpace::new().validate().is_err()); // all axes empty
        let unknown = DesignSpace::default_for(&["alexnet".to_string()]);
        assert!(unknown.validate().is_err());
        let wide = DesignSpace::default_for(&["resnet20".to_string()])
            .with_sizes(&[CrossbarDims { rows: 128, cols: 256 }]);
        assert!(wide.validate().is_err());
    }

    #[test]
    fn arch_kind_round_trips_and_matches_baseline_names() {
        for a in ArchKind::ALL {
            assert_eq!(ArchKind::by_key(a.key()), Some(a));
        }
        assert_eq!(ArchKind::AdcSar7.name(), "ADC-7b (SAR)");
        assert_eq!(ArchKind::HcimTernary.name(), "HCiM (Ternary)");
    }

    #[test]
    fn point_config_applies_axes() {
        let p = DesignPoint {
            workload: "resnet20".into(),
            xbar: CrossbarDims { rows: 64, cols: 64 },
            node: TechNode::N65,
            arch: ArchKind::AdcFlash4,
        };
        let cfg = p.config();
        assert_eq!(cfg.xbar.rows, 64);
        assert_eq!(cfg.node, TechNode::N65);
        assert_eq!(p.key(), "resnet20|64x64|65nm|adc4");
        // imagenet workloads use the imagenet base precision
        let q = DesignPoint { workload: "resnet18".into(), ..p };
        assert_eq!(q.config().w_bits, 3);
    }

    #[test]
    fn arch_names_flow_into_simulator() {
        let p = DesignPoint {
            workload: "resnet20".into(),
            xbar: CrossbarDims { rows: 128, cols: 128 },
            node: TechNode::N32,
            arch: ArchKind::HcimBinary,
        };
        assert_eq!(p.arch().name(), "HCiM (Binary)");
    }
}
