//! Parallel Monte Carlo robustness harness.
//!
//! N independent trials of [`crate::nonideal::inject::run_trial`] fan out
//! over [`crate::util::threadpool::ThreadPool`]. Per-trial seeds are drawn
//! from a single SplitMix64 stream over the master seed
//! ([`trial_seeds`]) and every trial is self-contained, so the aggregated
//! report is **byte-identical for any worker count** — the pool's
//! order-preserving `map` scatters results back into trial order before
//! any statistics are computed.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::config::hardware::HcimConfig;
use crate::journal::{self, TrialRecord, TrialStatus};
use crate::model::graph::Graph;
use crate::nonideal::inject::run_trial;
use crate::nonideal::models::NonIdealityParams;
use crate::nonideal::report::RobustnessReport;
use crate::obs::{self, instrument, Progress};
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use crate::util::threadpool::ThreadPool;

/// Monte Carlo run configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MonteCarloCfg {
    /// Number of independent trials (≥ 1).
    pub trials: usize,
    /// Master seed; each trial's seed derives from it via SplitMix64.
    pub seed: u64,
    /// Worker threads (0 = one per core). Any value yields identical
    /// results; it only changes wall-clock time.
    pub workers: usize,
}

impl Default for MonteCarloCfg {
    fn default() -> Self {
        MonteCarloCfg { trials: 32, seed: 42, workers: 0 }
    }
}

/// Headline metrics of one trial.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrialMetrics {
    /// The trial's derived seed.
    pub seed: u64,
    /// Fraction of comparator decisions whose PSQ code flipped.
    pub flip_rate: f64,
    /// Fraction of ideal ternary zero codes corrupted to ±1.
    pub zero_corruption_rate: f64,
    /// Mean |ΔPS| per column, normalized by the PS register full scale.
    pub disagreement: f64,
}

/// Derive `n` independent trial seeds from `master` via SplitMix64 (never
/// sequential integers — neighbouring integer seeds correlate in many
/// generators; SplitMix64 outputs do not).
pub fn trial_seeds(master: u64, n: usize) -> Vec<u64> {
    let mut s = master;
    (0..n).map(|_| splitmix64(&mut s)).collect()
}

/// Run the Monte Carlo: `mc.trials` seeded trials of `graph` on `cfg`
/// under `ni`, in parallel, aggregated into a [`RobustnessReport`].
pub fn run_monte_carlo(
    graph: &Graph,
    cfg: &HcimConfig,
    ni: &NonIdealityParams,
    mc: &MonteCarloCfg,
) -> RobustnessReport {
    run_monte_carlo_journaled(graph, cfg, ni, mc, None)
        .expect("journal-less monte carlo cannot fail")
}

/// [`run_monte_carlo`] with optional journal-backed durability and
/// resume. With `journal_dir` set, every completed trial is appended to
/// the journal as it finishes, and trials whose key already has a
/// successful record are loaded instead of re-run — the resumed report is
/// byte-identical to an uninterrupted one because trial seeds are
/// prefix-stable in the master seed and metric f64s round-trip exactly.
pub fn run_monte_carlo_journaled(
    graph: &Graph,
    cfg: &HcimConfig,
    ni: &NonIdealityParams,
    mc: &MonteCarloCfg,
    journal_dir: Option<&Path>,
) -> crate::Result<RobustnessReport> {
    assert!(mc.trials >= 1, "monte carlo needs at least one trial");
    let _span = obs::wall_span("mc.run");
    let t0 = Instant::now();
    let seeds = trial_seeds(mc.seed, mc.trials);
    let ni_fp = ni.fingerprint();
    let ctx = Arc::new((graph.clone(), cfg.clone(), *ni));

    // Resolve what the journal already holds (empty without --journal).
    let mut slots: Vec<Option<TrialMetrics>> = vec![None; mc.trials];
    let keys: Vec<String> = seeds
        .iter()
        .enumerate()
        .map(|(i, &s)| mc_trial_key(&ctx.0.name, cfg, ni_fp, mc.seed, i, s))
        .collect();
    let mut sink = None;
    if let Some(dir) = journal_dir {
        let contents = journal::read_dir(dir)?;
        let completed = contents.latest_ok_by_key();
        for (i, key) in keys.iter().enumerate() {
            if let Some(rec) = completed.get(key.as_str()) {
                slots[i] = trial_from_json(&rec.metrics, rec.seed);
            }
        }
        let pending_n = slots.iter().filter(|s| s.is_none()).count() as u64;
        let writer = journal::JournalWriter::create(dir, "robustness")?;
        sink = Some(journal::JournalSink::new(
            writer,
            "robustness",
            pending_n,
            Some(Progress::new("mc.trials", pending_n)),
            Some(journal::HEARTBEAT_EVERY_MS),
        ));
    }

    let pending: Vec<(usize, u64, String)> = slots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.is_none())
        .map(|(i, _)| (i, seeds[i], keys[i].clone()))
        .collect();
    let executed = pending.len();
    let progress = sink
        .is_none()
        .then(|| Arc::new(Progress::new("mc.trials", executed as u64)));

    let worker_ctx = Arc::clone(&ctx);
    let worker_sink = sink.clone();
    let worker = move |(i, seed, key): (usize, u64, String)| -> (usize, TrialMetrics) {
        let before = instrument::global().counter_values();
        let trial_t0 = Instant::now();
        let t = run_one(&worker_ctx, seed);
        if let Some(sink) = &worker_sink {
            let rec = TrialRecord {
                sweep: "robustness".to_string(),
                key: key.clone(),
                fingerprint: ni_fp,
                seed,
                status: TrialStatus::Ok,
                metrics: trial_to_json(&t),
                virt_ns: None,
                wall_ms: trial_t0.elapsed().as_secs_f64() * 1e3,
                unix_ms: journal::now_unix_ms(),
                instruments: journal::counter_delta(
                    &before,
                    &instrument::global().counter_values(),
                ),
            };
            if let Err(e) = sink.append_trial(&rec) {
                crate::log_warn!("journal append failed for {key}: {e}");
            }
        } else if let Some(progress) = &progress {
            progress.tick();
        }
        (i, t)
    };
    let fresh: Vec<(usize, TrialMetrics)> = if pending.len() <= 1 || mc.workers == 1 {
        // serial path: also used when a trial runs inside another pool's
        // worker (e.g. the DSE sweep), avoiding nested pool spawns
        pending.into_iter().map(worker).collect()
    } else {
        let workers = if mc.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            mc.workers
        };
        let pool = ThreadPool::new(workers.min(pending.len()).max(1));
        pool.map(pending, worker)
    };
    for (i, t) in fresh {
        slots[i] = Some(t);
    }
    let inst = instrument::global();
    inst.counter("mc.trials").add(executed as u64);
    inst.gauge("mc.trial_rate_per_s")
        .set_max((executed as f64 / t0.elapsed().as_secs_f64().max(1e-9)) as u64);
    if let Some(sink) = &sink {
        sink.finish();
    }
    let trials: Vec<TrialMetrics> =
        slots.into_iter().map(|s| s.expect("all slots filled")).collect();
    Ok(RobustnessReport::build(&ctx.0.name, &ctx.1, ni, mc.seed, trials))
}

/// Stable journal key of one Monte Carlo trial. Embeds everything that
/// invalidates the result: model version, workload, precision mode,
/// crossbar geometry, tech node, non-ideality fingerprint, master seed,
/// trial index, and the derived trial seed.
fn mc_trial_key(
    model: &str,
    cfg: &HcimConfig,
    ni_fp: u64,
    master: u64,
    idx: usize,
    seed: u64,
) -> String {
    format!(
        "{}|mc|{model}|{}|{}x{}|{:.0}nm|ni{ni_fp:016x}|m{master:016x}|t{idx}|s{seed:016x}",
        crate::nonideal::MODEL_VERSION,
        cfg.mode.precision_label(),
        cfg.xbar.rows,
        cfg.xbar.cols,
        cfg.node.nm,
    )
}

/// Journal metrics payload of one trial (field names mirror the
/// per-trial columns of [`RobustnessReport::to_json`]).
fn trial_to_json(t: &TrialMetrics) -> Json {
    let mut m = BTreeMap::new();
    m.insert("flip_rate".to_string(), Json::Num(t.flip_rate));
    m.insert("zero_corruption_rate".to_string(), Json::Num(t.zero_corruption_rate));
    m.insert("ps_disagreement".to_string(), Json::Num(t.disagreement));
    Json::Obj(m)
}

/// Parse [`trial_to_json`] output back; `None` re-runs the trial.
fn trial_from_json(j: &Json, seed: u64) -> Option<TrialMetrics> {
    Some(TrialMetrics {
        seed,
        flip_rate: j.num_field("flip_rate").ok()?,
        zero_corruption_rate: j.num_field("zero_corruption_rate").ok()?,
        disagreement: j.num_field("ps_disagreement").ok()?,
    })
}

fn run_one(ctx: &(Graph, HcimConfig, NonIdealityParams), seed: u64) -> TrialMetrics {
    let t = run_trial(&ctx.0, &ctx.1, &ctx.2, seed);
    TrialMetrics {
        seed,
        flip_rate: t.flip_rate(),
        zero_corruption_rate: t.zero_corruption_rate(),
        disagreement: t.disagreement(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn small_cfg() -> HcimConfig {
        let mut cfg = HcimConfig::config_a();
        cfg.xbar.rows = 32;
        cfg.xbar.cols = 32;
        cfg
    }

    #[test]
    fn trial_seeds_are_splitmix_not_sequential() {
        let seeds = trial_seeds(0, 8);
        assert_eq!(seeds.len(), 8);
        // distinct, and not master+i
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 8);
        for (i, &s) in seeds.iter().enumerate() {
            assert_ne!(s, i as u64, "sequential seeds are forbidden");
        }
        // reproducible
        assert_eq!(seeds, trial_seeds(0, 8));
        assert_ne!(seeds, trial_seeds(1, 8));
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let g = zoo::resnet20();
        let cfg = small_cfg();
        let ni = NonIdealityParams::default_for(cfg.node);
        let serial = run_monte_carlo(
            &g,
            &cfg,
            &ni,
            &MonteCarloCfg { trials: 6, seed: 77, workers: 1 },
        );
        let parallel = run_monte_carlo(
            &g,
            &cfg,
            &ni,
            &MonteCarloCfg { trials: 6, seed: 77, workers: 4 },
        );
        assert_eq!(serial.trials, parallel.trials, "trial metrics must be identical");
        assert_eq!(
            serial.to_json().to_string(),
            parallel.to_json().to_string(),
            "whole report must be byte-identical"
        );
    }

    #[test]
    fn ideal_magnitudes_measure_exactly_zero() {
        let g = zoo::resnet20();
        let cfg = small_cfg();
        let r = run_monte_carlo(
            &g,
            &cfg,
            &NonIdealityParams::ideal(),
            &MonteCarloCfg { trials: 4, seed: 1, workers: 2 },
        );
        for t in &r.trials {
            assert_eq!(t.flip_rate, 0.0);
            assert_eq!(t.zero_corruption_rate, 0.0);
            assert_eq!(t.disagreement, 0.0);
        }
        assert_eq!(r.flip.mean, 0.0);
        assert_eq!(r.flip.max, 0.0);
    }

    #[test]
    fn summaries_cover_all_trials() {
        let g = zoo::vgg9();
        let cfg = small_cfg();
        let ni = NonIdealityParams::default_for(cfg.node);
        let r = run_monte_carlo(&g, &cfg, &ni, &MonteCarloCfg { trials: 5, seed: 3, workers: 0 });
        assert_eq!(r.trials.len(), 5);
        assert_eq!(r.flip.n, 5);
        assert!(r.flip.mean > 0.0);
        assert!(r.flip.min <= r.flip.p50 && r.flip.p50 <= r.flip.max);
    }
}
