//! Analog non-ideality modeling and Monte Carlo robustness analysis.
//!
//! HCiM replaces the ADC with a 1/1.5-bit comparator bank (paper §4.2),
//! which makes accuracy hostage to analog effects the ideal functional
//! model ignores: conductance variation, stuck-at cell faults, bitline IR
//! drop, and comparator input-referred offset all shift the analog partial
//! sum — exactly the quantity the paper's PSQ algorithm (§4.1, Fig. 2(a))
//! thresholds into ternary codes, and the ternary zero codes (§4.2.2,
//! Fig. 2(c)) are what the DCiM sparsity gating banks its energy savings
//! on. This subsystem quantifies how fragile those decisions are:
//!
//! * [`models`] — composable, seed-deterministic perturbation models
//!   ([`NonIdealityParams`], [`CrossbarPerturbation`]), magnitudes
//!   scalable per [`crate::sim::tech::TechNode`];
//! * [`inject`] — the perturbed functional PSQ path
//!   ([`inject::psq_mvm_nonideal`], hot path: [`inject::NonIdealEngine`]
//!   on packed bit-planes with precomputed stuck-at word masks) and
//!   layer-by-layer ideal-vs-perturbed comparison over
//!   [`crate::model::zoo`] graphs ([`inject::run_trial`]);
//! * [`monte_carlo`] — N seeded trials fanned out on the worker pool
//!   ([`run_monte_carlo`]), byte-identical for any worker count;
//! * [`report`] — [`RobustnessReport`]: mean/std/percentile summaries,
//!   ASCII tables, JSON + CSV export.
//!
//! Entry points: the `hcim robustness` CLI subcommand,
//! `hcim dse --robustness` (adds a flip-rate objective to the Pareto
//! frontier), `examples/variation_sweep.rs`, or programmatically:
//!
//! ```no_run
//! use hcim::config::hardware::HcimConfig;
//! use hcim::model::zoo;
//! use hcim::nonideal::{run_monte_carlo, MonteCarloCfg, NonIdealityParams};
//! let cfg = HcimConfig::config_a();
//! let ni = NonIdealityParams::default_for(cfg.node);
//! let report = run_monte_carlo(
//!     &zoo::resnet20(),
//!     &cfg,
//!     &ni,
//!     &MonteCarloCfg::default(),
//! );
//! report.table().print();
//! ```
//! (`no_run` for the same reason as `util::prop`: doctest binaries cannot
//! resolve their rpath in this offline image.)

pub mod models;
pub mod inject;
pub mod monte_carlo;
pub mod report;

/// Version tag of the non-ideality model family; bumped when the
/// perturbation math changes, so DSE cache entries carrying robustness
/// values invalidate correctly.
pub const MODEL_VERSION: &str = "ni-v1";

pub use inject::{
    psq_mvm_nonideal, psq_mvm_nonideal_scalar, run_trial, run_trial_scalar, LayerOutcome,
    NonIdealEngine, NonIdealOutput, TrialOutcome,
};
pub use models::{CellFault, CrossbarPerturbation, NonIdealityParams};
pub use monte_carlo::{
    run_monte_carlo, run_monte_carlo_journaled, trial_seeds, MonteCarloCfg, TrialMetrics,
};
pub use report::RobustnessReport;
