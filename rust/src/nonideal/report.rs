//! Robustness report: aggregation, ASCII tables, JSON/CSV export.
//!
//! Everything rendered here is a pure function of the trial metrics, which
//! are themselves a pure function of (model, config, params, master seed)
//! — so two runs with the same seed produce byte-identical artifacts no
//! matter how many workers executed the trials. Seeds are serialized as
//! hex strings (JSON numbers cannot hold a full `u64`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::hardware::HcimConfig;
use crate::nonideal::models::NonIdealityParams;
use crate::nonideal::monte_carlo::TrialMetrics;
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::Table;

/// Aggregated output of one Monte Carlo robustness run.
#[derive(Clone, Debug)]
pub struct RobustnessReport {
    /// Zoo model name.
    pub model: String,
    /// PSQ precision label ("1" binary, "1.5" ternary — paper Table 2).
    pub mode: String,
    /// Evaluation node label ("32nm", …).
    pub node: String,
    /// Crossbar geometry label ("128x128").
    pub xbar: String,
    /// Master seed of the run.
    pub seed: u64,
    /// Magnitudes the trials ran under.
    pub params: NonIdealityParams,
    /// Per-trial metrics, in trial order.
    pub trials: Vec<TrialMetrics>,
    /// Summary over per-trial flip rates.
    pub flip: Summary,
    /// Summary over per-trial zero-code corruption rates.
    pub zero: Summary,
    /// Summary over per-trial PS disagreement scores.
    pub disagreement: Summary,
}

impl RobustnessReport {
    /// Aggregate trial metrics into a report.
    pub fn build(
        model: &str,
        cfg: &HcimConfig,
        params: &NonIdealityParams,
        seed: u64,
        trials: Vec<TrialMetrics>,
    ) -> RobustnessReport {
        let flips: Vec<f64> = trials.iter().map(|t| t.flip_rate).collect();
        let zeros: Vec<f64> = trials.iter().map(|t| t.zero_corruption_rate).collect();
        let dis: Vec<f64> = trials.iter().map(|t| t.disagreement).collect();
        RobustnessReport {
            model: model.to_string(),
            mode: cfg.mode.precision_label().to_string(),
            node: format!("{:.0}nm", cfg.node.nm),
            xbar: format!("{}x{}", cfg.xbar.rows, cfg.xbar.cols),
            seed,
            params: *params,
            flip: Summary::of(&flips),
            zero: Summary::of(&zeros),
            disagreement: Summary::of(&dis),
            trials,
        }
    }

    /// Summary statistics table (one row per metric).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "robustness — {} ({}-bit PSQ, {}, {} crossbar, {} trials, seed {:#x})",
                self.model,
                self.mode,
                self.node,
                self.xbar,
                self.trials.len(),
                self.seed
            ),
            &["Metric", "Mean", "Std", "Min", "P50", "P90", "P99", "Max"],
        );
        for (name, s) in [
            ("PSQ code flip rate", &self.flip),
            ("zero-code corruption", &self.zero),
            ("PS disagreement", &self.disagreement),
        ] {
            t.row(&[
                name.to_string(),
                format!("{:.5}", s.mean),
                format!("{:.5}", s.std_dev),
                format!("{:.5}", s.min),
                format!("{:.5}", s.p50),
                format!("{:.5}", s.p90),
                format!("{:.5}", s.p99),
                format!("{:.5}", s.max),
            ]);
        }
        t
    }

    /// The non-ideality magnitudes the run used.
    pub fn params_table(&self) -> Table {
        let mut t = Table::new(
            "non-ideality magnitudes",
            &["sigma_G", "stuck_on", "stuck_off", "ir_drop", "sigma_cmp (LSB)"],
        );
        t.row(&[
            format!("{:.4}", self.params.sigma_g),
            format!("{:.5}", self.params.stuck_on),
            format!("{:.5}", self.params.stuck_off),
            format!("{:.4}", self.params.ir_drop),
            format!("{:.4}", self.params.sigma_cmp),
        ]);
        t
    }

    /// JSON document (metadata + summaries + per-trial rows).
    pub fn to_json(&self) -> Json {
        let summary = |s: &Summary| {
            let mut o = BTreeMap::new();
            o.insert("n".into(), Json::Num(s.n as f64));
            o.insert("mean".into(), Json::Num(s.mean));
            o.insert("std".into(), Json::Num(s.std_dev));
            o.insert("min".into(), Json::Num(s.min));
            o.insert("p50".into(), Json::Num(s.p50));
            o.insert("p90".into(), Json::Num(s.p90));
            o.insert("p99".into(), Json::Num(s.p99));
            o.insert("max".into(), Json::Num(s.max));
            Json::Obj(o)
        };
        let mut params = BTreeMap::new();
        params.insert("sigma_g".into(), Json::Num(self.params.sigma_g));
        params.insert("stuck_on".into(), Json::Num(self.params.stuck_on));
        params.insert("stuck_off".into(), Json::Num(self.params.stuck_off));
        params.insert("ir_drop".into(), Json::Num(self.params.ir_drop));
        params.insert("sigma_cmp".into(), Json::Num(self.params.sigma_cmp));
        let per_trial: Vec<Json> = self
            .trials
            .iter()
            .map(|t| {
                let mut o = BTreeMap::new();
                o.insert("seed".into(), Json::Str(format!("{:#018x}", t.seed)));
                o.insert("flip_rate".into(), Json::Num(t.flip_rate));
                o.insert(
                    "zero_corruption_rate".into(),
                    Json::Num(t.zero_corruption_rate),
                );
                o.insert("ps_disagreement".into(), Json::Num(t.disagreement));
                Json::Obj(o)
            })
            .collect();
        let mut metrics = BTreeMap::new();
        metrics.insert("flip_rate".into(), summary(&self.flip));
        metrics.insert("zero_corruption_rate".into(), summary(&self.zero));
        metrics.insert("ps_disagreement".into(), summary(&self.disagreement));
        let mut top = BTreeMap::new();
        top.insert("version".into(), Json::Num(1.0));
        top.insert("model".into(), Json::Str(self.model.clone()));
        top.insert("mode".into(), Json::Str(self.mode.clone()));
        top.insert("node".into(), Json::Str(self.node.clone()));
        top.insert("xbar".into(), Json::Str(self.xbar.clone()));
        top.insert("seed".into(), Json::Str(format!("{:#018x}", self.seed)));
        top.insert("trials".into(), Json::Num(self.trials.len() as f64));
        top.insert("params".into(), Json::Obj(params));
        top.insert("metrics".into(), Json::Obj(metrics));
        top.insert("per_trial".into(), Json::Arr(per_trial));
        Json::Obj(top)
    }

    /// CSV export (one row per trial).
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("trial,seed,flip_rate,zero_corruption_rate,ps_disagreement\n");
        for (i, t) in self.trials.iter().enumerate() {
            out.push_str(&format!(
                "{},{:#018x},{:.6},{:.6},{:.6}\n",
                i, t.seed, t.flip_rate, t.zero_corruption_rate, t.disagreement
            ));
        }
        out
    }

    /// Write `robustness.json` and `robustness.csv` under `dir`.
    pub fn write(&self, dir: &Path) -> crate::Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        let json_path = dir.join("robustness.json");
        let csv_path = dir.join("robustness.csv");
        std::fs::write(&json_path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", json_path.display()))?;
        std::fs::write(&csv_path, self.to_csv())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", csv_path.display()))?;
        Ok((json_path, csv_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic() -> RobustnessReport {
        let cfg = HcimConfig::config_a();
        let trials = vec![
            TrialMetrics {
                seed: 0xAA,
                flip_rate: 0.01,
                zero_corruption_rate: 0.002,
                disagreement: 0.0005,
            },
            TrialMetrics {
                seed: 0xBB,
                flip_rate: 0.03,
                zero_corruption_rate: 0.004,
                disagreement: 0.0015,
            },
        ];
        RobustnessReport::build(
            "resnet20",
            &cfg,
            &NonIdealityParams::default_for(cfg.node),
            42,
            trials,
        )
    }

    #[test]
    fn build_aggregates_summaries() {
        let r = synthetic();
        assert_eq!(r.trials.len(), 2);
        assert_eq!(r.flip.n, 2);
        assert!((r.flip.mean - 0.02).abs() < 1e-12);
        assert_eq!(r.mode, "1.5");
        assert_eq!(r.node, "32nm");
        assert_eq!(r.xbar, "128x128");
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let r = synthetic();
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.str_field("model").unwrap(), "resnet20");
        assert_eq!(parsed.num_field("trials").unwrap(), 2.0);
        let per_trial = parsed.get("per_trial").unwrap().as_arr().unwrap();
        assert_eq!(per_trial.len(), 2);
        assert_eq!(per_trial[0].str_field("seed").unwrap(), "0x00000000000000aa");
        let flip = parsed.get("metrics").unwrap().get("flip_rate").unwrap();
        assert!((flip.num_field("mean").unwrap() - 0.02).abs() < 1e-9);
    }

    #[test]
    fn csv_has_header_plus_trial_rows() {
        let r = synthetic();
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("trial,seed,flip_rate"));
        assert!(lines[1].starts_with("0,0x00000000000000aa,0.010000"));
    }

    #[test]
    fn tables_render() {
        let r = synthetic();
        let t = r.table().render();
        assert!(t.contains("PSQ code flip rate"));
        assert!(t.contains("zero-code corruption"));
        let p = r.params_table().render();
        assert!(p.contains("sigma_G"));
    }

    #[test]
    fn write_emits_both_files() {
        let dir = std::env::temp_dir().join("hcim_nonideal_report_write");
        let _ = std::fs::remove_dir_all(&dir);
        let r = synthetic();
        let (j, c) = r.write(&dir).unwrap();
        assert!(j.exists() && c.exists());
        assert!(Json::parse(&std::fs::read_to_string(j).unwrap()).is_ok());
    }
}
