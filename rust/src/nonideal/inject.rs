//! Injection of non-idealities into the functional PSQ path.
//!
//! [`psq_mvm_nonideal`] mirrors [`crate::quant::psq::psq_mvm`] bit-step by
//! bit-step but perturbs the *analog* partial sum between the crossbar
//! popcount ([`crate::quant::bits::bit_dot`]'s role) and the comparator
//! decision: weight bit-slices are stuck-at-fault masked, each conducting
//! cell contributes its perturbed current (log-normal conductance ×
//! IR-drop attenuation), and the decision runs through a real
//! [`ComparatorBank`] with per-column input-referred offsets. Everything
//! downstream (scale factors, saturating PS accumulation) is the ideal
//! digital path — HCiM's DCiM array is digital and assumed correct.
//!
//! The hot path runs on [`NonIdealEngine`], which programs the faulted
//! crossbar once per (layer, trial) on the packed
//! [`crate::quant::bits::PackedBits`] representation; the byte-per-bit
//! scalar implementation survives as [`psq_mvm_nonideal_scalar`], the
//! bit-exact oracle the engine is property-tested against.
//!
//! [`run_trial`] applies this layer-by-layer to a [`crate::model::zoo`]
//! graph: for every MVM layer it synthesizes a representative
//! crossbar-sized problem from a forked per-layer generator, runs the
//! ideal and the perturbed path on identical inputs, and counts PSQ-code
//! flips, ternary zero-code corruptions, and partial-sum disagreement.

use crate::config::hardware::HcimConfig;
use crate::model::graph::Graph;
use crate::nonideal::models::{CrossbarPerturbation, NonIdealityParams};
use crate::quant::bits::{
    assert_bit_widths, input_bitplane, weight_bitslice, ColBlocks, Mat, PackedBits,
};
use crate::quant::fixed::sat_add;
use crate::quant::psq::{
    chunk_images, psq_mvm_scalar, quantize_ps, PsqEngine, PsqLayerParams, PsqOutput,
};
use crate::sim::components::comparator::ComparatorBank;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Output of one perturbed PSQ-MVM (same layout as
/// [`crate::quant::psq::PsqOutput`], with the analog pre-comparator values
/// kept as floats).
#[derive(Clone, Debug)]
pub struct NonIdealOutput {
    /// Final per-physical-column partial sums.
    pub ps: Vec<i64>,
    /// Comparator codes, `[x_bits × phys_cols]` row-major.
    pub p: Vec<i8>,
    /// Perturbed analog column values, same layout.
    pub analog: Vec<f64>,
}

impl NonIdealOutput {
    /// All-zero output for a `phys_cols`-column crossbar over `x_bits`
    /// streams. Pass to [`NonIdealEngine::mvm_into`] and reuse.
    pub fn zeroed(phys_cols: usize, x_bits: u32) -> NonIdealOutput {
        NonIdealOutput {
            ps: vec![0; phys_cols],
            p: vec![0; x_bits as usize * phys_cols],
            analog: vec![0.0; x_bits as usize * phys_cols],
        }
    }

    fn reset(&mut self, phys_cols: usize, x_bits: u32) {
        let codes = x_bits as usize * phys_cols;
        self.ps.clear();
        self.ps.resize(phys_cols, 0);
        self.p.clear();
        self.p.resize(codes, 0);
        self.analog.clear();
        self.analog.resize(codes, 0.0);
    }
}

/// A perturbed crossbar programmed once per (layer, trial), serving
/// repeated MVMs on the packed representation.
///
/// Programming applies the stuck-at fault map as precomputed per-column
/// OR (stuck-ON) / AND-NOT (stuck-OFF) word masks over the packed
/// bit-slices, and snapshots the cell gains column-major so the inner
/// loop streams one contiguous `f64` slice per column. Evaluation packs
/// each input bit-plane once and accumulates the perturbed analog value
/// by iterating **only the set bits** of `(col & plane)` via
/// `trailing_zeros` — work proportional to the active cells (the
/// simulator-side mirror of the paper's §4.2.2 sparsity energy argument) —
/// in ascending row order, so the `f64` summation order (and therefore
/// every Monte Carlo artifact downstream) is bit-identical to the scalar
/// oracle [`psq_mvm_nonideal_scalar`].
#[derive(Clone, Debug)]
pub struct NonIdealEngine {
    params: PsqLayerParams,
    rows: usize,
    phys_cols: usize,
    /// Column-blocked bit-slice columns with stuck-at masks already
    /// applied.
    blocks: ColBlocks,
    /// Column-major cell current gains: `gains[c * rows + r]`.
    gains: Vec<f64>,
    /// Per-column comparator input-referred offsets.
    offsets: Vec<f64>,
    /// Input bit-plane scratch, repacked per stream.
    plane: PackedBits,
}

impl NonIdealEngine {
    /// Program the perturbed crossbar (the once-per-(layer, trial) cost).
    pub fn program(
        w: &Mat,
        params: &PsqLayerParams,
        pert: &CrossbarPerturbation,
    ) -> NonIdealEngine {
        let rows = w.rows;
        let phys_cols = w.cols * params.w_bits as usize;
        assert_eq!(pert.rows, rows, "perturbation row mismatch");
        assert_eq!(pert.phys_cols, phys_cols, "perturbation column mismatch");
        assert_eq!(
            params.scales.len(),
            params.x_bits as usize * phys_cols,
            "scale factor table shape mismatch"
        );

        let mut cols = Vec::with_capacity(phys_cols);
        let mut on = PackedBits::zeros(rows);
        let mut off = PackedBits::zeros(rows);
        for lc in 0..w.cols {
            let col = w.col(lc);
            for i in 0..params.w_bits {
                let c = cols.len();
                on.reset(rows);
                off.reset(rows);
                for r in 0..rows {
                    if pert.is_stuck_on(r, c) {
                        on.set(r, 1);
                    }
                    if pert.is_stuck_off(r, c) {
                        off.set(r, 1);
                    }
                }
                let mut bits = PackedBits::from_bitslice(&col, i, params.w_bits);
                bits.or_assign(&on);
                bits.andnot_assign(&off);
                cols.push(bits);
            }
        }

        let mut gains = Vec::with_capacity(rows * phys_cols);
        for c in 0..phys_cols {
            for r in 0..rows {
                gains.push(pert.cell_gain(r, c));
            }
        }

        NonIdealEngine {
            offsets: pert.comparator_offsets().to_vec(),
            params: params.clone(),
            rows,
            phys_cols,
            blocks: ColBlocks::from_cols(&cols),
            gains,
            plane: PackedBits::zeros(rows),
        }
    }

    /// One full perturbed MVM (allocates the output; see
    /// [`NonIdealEngine::mvm_into`] for the reuse path).
    pub fn mvm(&mut self, x: &[i64]) -> NonIdealOutput {
        let mut out = NonIdealOutput::zeroed(self.phys_cols, self.params.x_bits);
        self.mvm_into(x, &mut out);
        out
    }

    /// One full perturbed MVM into a reusable output buffer — no heap
    /// allocation once `out` and the plane scratch have warmed up.
    pub fn mvm_into(&mut self, x: &[i64], out: &mut NonIdealOutput) {
        let NonIdealEngine { params, rows, phys_cols, blocks, gains, offsets, plane } = self;
        nonideal_mvm_core(params, *rows, *phys_cols, blocks, gains, offsets, plane, x, out);
    }

    /// Shared-engine perturbed MVM with caller-supplied bit-plane scratch
    /// (the `&self` form for concurrent image streams; see
    /// [`NonIdealEngine::mvm_batch`]). Identical output to
    /// [`NonIdealEngine::mvm_into`].
    pub fn mvm_with(&self, x: &[i64], plane: &mut PackedBits, out: &mut NonIdealOutput) {
        nonideal_mvm_core(
            &self.params,
            self.rows,
            self.phys_cols,
            &self.blocks,
            &self.gains,
            &self.offsets,
            plane,
            x,
            out,
        );
    }

    /// Evaluate a batch of input images against the shared programmed
    /// perturbation, fanned out over `pool` in fixed-size chunks.
    ///
    /// Deterministic: `out[i]` is exactly [`NonIdealEngine::mvm_into`] of
    /// `images[i]` — including the `f64` analog sums — for any pool size.
    pub fn mvm_batch(
        self: &Arc<Self>,
        images: Vec<Vec<i64>>,
        pool: &ThreadPool,
    ) -> Vec<NonIdealOutput> {
        let engine = Arc::clone(self);
        let outs = pool.map(chunk_images(images), move |chunk| {
            let mut plane = PackedBits::zeros(0);
            chunk
                .iter()
                .map(|x| {
                    let mut out = NonIdealOutput::zeroed(engine.phys_cols, engine.params.x_bits);
                    engine.mvm_with(x, &mut plane, &mut out);
                    out
                })
                .collect::<Vec<_>>()
        });
        outs.into_iter().flatten().collect()
    }
}

/// The blocked perturbed-MVM sweep shared by [`NonIdealEngine::mvm_into`]
/// and [`NonIdealEngine::mvm_with`].
///
/// The perturbed column current is Σ gains over the conducting cells,
/// accumulated directly into `out.analog` by the blocked `(col, row)`
/// visitor — work proportional to the active cells (the simulator-side
/// mirror of the paper's §4.2.2 sparsity energy argument). Within each
/// column the visitor ascends rows exactly as the unblocked scan did, so
/// every per-column `f64` sum is bit-identical to the scalar oracle
/// [`psq_mvm_nonideal_scalar`] even though columns interleave. The
/// comparator decision is the inlined form of
/// [`ComparatorBank::compare_analog`]'s per-column expression
/// (`quantize_ps(a + offset − θ)`), evaluated in the same order with the
/// same associativity.
#[allow(clippy::too_many_arguments)]
fn nonideal_mvm_core(
    params: &PsqLayerParams,
    rows: usize,
    phys_cols: usize,
    blocks: &ColBlocks,
    gains: &[f64],
    offsets: &[f64],
    plane: &mut PackedBits,
    x: &[i64],
    out: &mut NonIdealOutput,
) {
    assert_eq!(x.len(), rows, "input/crossbar row mismatch");
    out.reset(phys_cols, params.x_bits);
    for j in 0..params.x_bits {
        plane.pack_bitplane(x, j);
        let base = j as usize * phys_cols;
        let analog = &mut out.analog[base..base + phys_cols];
        blocks.and_for_each_one(plane, |c, r| analog[c] += gains[c * rows + r]);
        for c in 0..phys_cols {
            let idx = base + c;
            let a = out.analog[idx];
            let p = quantize_ps(a + offsets[c] - params.theta, params.mode);
            out.p[idx] = p;
            if p != 0 {
                let s = params.scales[idx];
                out.ps[c] = sat_add(out.ps[c], p as i64 * s, params.ps_bits);
            }
        }
    }
}

/// Perturbed PSQ matrix-vector product over one crossbar.
///
/// With `pert` the exact identity this is code- and PS-identical to
/// [`crate::quant::psq::psq_mvm`] (the analog value of a column is then
/// the integer popcount, exactly representable in `f64`).
///
/// Thin program-then-eval wrapper over [`NonIdealEngine`]; callers issuing
/// many MVMs against one programmed perturbation should hold the engine.
pub fn psq_mvm_nonideal(
    w: &Mat,
    x: &[i64],
    params: &PsqLayerParams,
    pert: &CrossbarPerturbation,
) -> NonIdealOutput {
    assert_eq!(w.rows, x.len(), "input/crossbar row mismatch");
    NonIdealEngine::program(w, params, pert).mvm(x)
}

/// The original byte-per-bit scalar implementation, kept verbatim as the
/// bit-exact oracle for [`psq_mvm_nonideal`] / [`NonIdealEngine`]
/// (equivalence — including identical `f64` analog sums — is
/// property-tested; the scalar path also anchors the before/after speedup
/// rows in `benches/hotpath.rs` and EXPERIMENTS.md §Perf).
pub fn psq_mvm_nonideal_scalar(
    w: &Mat,
    x: &[i64],
    params: &PsqLayerParams,
    pert: &CrossbarPerturbation,
) -> NonIdealOutput {
    assert_eq!(w.rows, x.len(), "input/crossbar row mismatch");
    let phys_cols = w.cols * params.w_bits as usize;
    assert_eq!(pert.rows, w.rows, "perturbation row mismatch");
    assert_eq!(pert.phys_cols, phys_cols, "perturbation column mismatch");
    assert_eq!(
        params.scales.len(),
        params.x_bits as usize * phys_cols,
        "scale factor table shape mismatch"
    );

    // program the crossbar: bit-sliced columns with stuck-at faults applied
    let mut colbits: Vec<Vec<u8>> = Vec::with_capacity(phys_cols);
    for lc in 0..w.cols {
        let col = w.col(lc);
        for i in 0..params.w_bits {
            let c = colbits.len();
            let mut bits = weight_bitslice(&col, i, params.w_bits);
            for (r, b) in bits.iter_mut().enumerate() {
                *b = pert.fault_bit(r, c, *b);
            }
            colbits.push(bits);
        }
    }

    let bank = ComparatorBank::new(params.mode, params.theta, phys_cols);
    let mut ps = vec![0i64; phys_cols];
    let mut p_all = vec![0i8; params.x_bits as usize * phys_cols];
    let mut analog_all = vec![0.0f64; params.x_bits as usize * phys_cols];
    for j in 0..params.x_bits {
        let xp = input_bitplane(x, j);
        let analog: Vec<f64> = (0..phys_cols)
            .map(|c| {
                let mut a = 0.0;
                for (r, (&wb, &xb)) in colbits[c].iter().zip(xp.iter()).enumerate() {
                    if (wb & xb) == 1 {
                        a += pert.cell_gain(r, c);
                    }
                }
                a
            })
            .collect();
        let codes = bank.compare_analog(&analog, pert.comparator_offsets());
        for (c, code) in codes.iter().enumerate() {
            let idx = j as usize * phys_cols + c;
            analog_all[idx] = analog[c];
            let p = code.decode();
            p_all[idx] = p;
            if p != 0 {
                ps[c] = sat_add(ps[c], p as i64 * params.scales[idx], params.ps_bits);
            }
        }
    }
    NonIdealOutput { ps, p: p_all, analog: analog_all }
}

/// Ideal-vs-perturbed comparison for one MVM layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerOutcome {
    /// Index of the layer in the graph's layer list.
    pub layer_index: usize,
    /// Comparator decisions compared (`x_bits × phys_cols`).
    pub codes: usize,
    /// Decisions whose PSQ code changed under perturbation.
    pub flips: usize,
    /// Ideal-path zero codes (the sparsity the DCiM gating exploits).
    pub ideal_zeros: usize,
    /// Ideal zeros that became non-zero — lost gating opportunities.
    pub zero_corruptions: usize,
    /// Physical columns compared.
    pub columns: usize,
    /// Σ|PS_ideal − PS_perturbed| over the columns.
    pub ps_l1: f64,
}

impl LayerOutcome {
    /// Compare the ideal and perturbed outputs of one crossbar MVM.
    pub fn compare(layer_index: usize, ideal: &PsqOutput, actual: &NonIdealOutput) -> LayerOutcome {
        assert_eq!(ideal.p.len(), actual.p.len());
        assert_eq!(ideal.ps.len(), actual.ps.len());
        let mut flips = 0;
        let mut ideal_zeros = 0;
        let mut zero_corruptions = 0;
        for (&pi, &pa) in ideal.p.iter().zip(&actual.p) {
            if pi != pa {
                flips += 1;
            }
            if pi == 0 {
                ideal_zeros += 1;
                if pa != 0 {
                    zero_corruptions += 1;
                }
            }
        }
        let ps_l1 = ideal
            .ps
            .iter()
            .zip(&actual.ps)
            .map(|(&a, &b)| (a - b).abs() as f64)
            .sum();
        LayerOutcome {
            layer_index,
            codes: ideal.p.len(),
            flips,
            ideal_zeros,
            zero_corruptions,
            columns: ideal.ps.len(),
            ps_l1,
        }
    }
}

/// One full Monte Carlo trial: every MVM layer of a model compared
/// ideal-vs-perturbed under a single seed.
#[derive(Clone, Debug, PartialEq)]
pub struct TrialOutcome {
    pub seed: u64,
    pub layers: Vec<LayerOutcome>,
    /// Full-scale magnitude of the PS register (`2^{ps_bits−1}`), the
    /// normalizer of [`TrialOutcome::disagreement`].
    pub ps_full_scale: f64,
}

impl TrialOutcome {
    /// Fraction of comparator decisions whose PSQ code flipped.
    pub fn flip_rate(&self) -> f64 {
        let codes: usize = self.layers.iter().map(|l| l.codes).sum();
        let flips: usize = self.layers.iter().map(|l| l.flips).sum();
        if codes == 0 { 0.0 } else { flips as f64 / codes as f64 }
    }

    /// Fraction of ideal ternary zero codes corrupted to ±1 (0 when the
    /// ideal path produced no zeros, e.g. binary PSQ).
    pub fn zero_corruption_rate(&self) -> f64 {
        let zeros: usize = self.layers.iter().map(|l| l.ideal_zeros).sum();
        let corrupted: usize = self.layers.iter().map(|l| l.zero_corruptions).sum();
        if zeros == 0 { 0.0 } else { corrupted as f64 / zeros as f64 }
    }

    /// End-to-end code-disagreement score: mean |ΔPS| per column,
    /// normalized by the PS register full scale (0 = bit-identical,
    /// 1 ≈ every column off by the whole register range).
    pub fn disagreement(&self) -> f64 {
        let cols: usize = self.layers.iter().map(|l| l.columns).sum();
        let l1: f64 = self.layers.iter().map(|l| l.ps_l1).sum();
        if cols == 0 { 0.0 } else { l1 / (cols as f64 * self.ps_full_scale) }
    }
}

/// Run one trial of `graph` on the PSQ periphery of `cfg` under `ni`.
///
/// Per-layer state (synthetic weights/activations in the config's code
/// ranges, calibrated PSQ parameters, and the sampled perturbation) comes
/// from a generator forked off the trial seed in layer order — fully
/// deterministic, and independent across trials by construction.
///
/// Hot path of `hcim robustness` and `hcim dse --robustness`
/// (trials × layers of this per Monte Carlo): both the ideal and the
/// perturbed MVM run on the packed engines, programmed once per
/// (layer, trial) and evaluated into output buffers reused across layers.
/// Bit-identical to [`run_trial_scalar`].
pub fn run_trial(
    graph: &Graph,
    cfg: &HcimConfig,
    ni: &NonIdealityParams,
    seed: u64,
) -> TrialOutcome {
    assert_bit_widths(cfg.w_bits, cfg.x_bits);
    let mut rng = Rng::new(seed);
    let w_lo = -(1i64 << (cfg.w_bits - 1));
    let w_hi = (1i64 << (cfg.w_bits - 1)) - 1;
    let x_hi = (1i64 << cfg.x_bits) - 1;
    let mut layers = Vec::new();
    let mut ideal = PsqOutput::zeroed(0, 0);
    let mut actual = NonIdealOutput::zeroed(0, 0);
    for ann in graph.annotate() {
        let Some(mvm) = ann.mvm else { continue };
        let mut lr = rng.fork();
        // one representative crossbar tile of the layer's mapping
        let rows = mvm.rows.min(cfg.xbar.rows).max(1);
        let max_logical = (cfg.xbar.cols / cfg.w_bits as usize).max(1);
        let cols = mvm.cols.min(max_logical).max(1);
        let w = Mat::from_fn(rows, cols, |_, _| lr.range_i64(w_lo, w_hi));
        let x: Vec<i64> = (0..rows).map(|_| lr.range_i64(0, x_hi)).collect();
        let params = PsqLayerParams::calibrated(
            &w,
            cfg.mode,
            cfg.w_bits,
            cfg.x_bits,
            cfg.ps_bits,
            &mut lr,
        );
        let pert =
            CrossbarPerturbation::sample(rows, cols * cfg.w_bits as usize, ni, &mut lr);
        PsqEngine::program(&w, &params).mvm_into(&x, &mut ideal);
        NonIdealEngine::program(&w, &params, &pert).mvm_into(&x, &mut actual);
        layers.push(LayerOutcome::compare(ann.index, &ideal, &actual));
    }
    TrialOutcome {
        seed,
        layers,
        ps_full_scale: (1i64 << (cfg.ps_bits - 1)) as f64,
    }
}

/// [`run_trial`] on the byte-per-bit scalar oracles
/// ([`psq_mvm_scalar`] / [`psq_mvm_nonideal_scalar`]) — the pre-packed
/// implementation, kept as the regression oracle (`run_trial` must match
/// it exactly for every seed) and as the "before" row of the
/// `robustness trial` benchmark in `benches/hotpath.rs`.
pub fn run_trial_scalar(
    graph: &Graph,
    cfg: &HcimConfig,
    ni: &NonIdealityParams,
    seed: u64,
) -> TrialOutcome {
    assert_bit_widths(cfg.w_bits, cfg.x_bits);
    let mut rng = Rng::new(seed);
    let w_lo = -(1i64 << (cfg.w_bits - 1));
    let w_hi = (1i64 << (cfg.w_bits - 1)) - 1;
    let x_hi = (1i64 << cfg.x_bits) - 1;
    let mut layers = Vec::new();
    for ann in graph.annotate() {
        let Some(mvm) = ann.mvm else { continue };
        let mut lr = rng.fork();
        let rows = mvm.rows.min(cfg.xbar.rows).max(1);
        let max_logical = (cfg.xbar.cols / cfg.w_bits as usize).max(1);
        let cols = mvm.cols.min(max_logical).max(1);
        let w = Mat::from_fn(rows, cols, |_, _| lr.range_i64(w_lo, w_hi));
        let x: Vec<i64> = (0..rows).map(|_| lr.range_i64(0, x_hi)).collect();
        let params = PsqLayerParams::calibrated(
            &w,
            cfg.mode,
            cfg.w_bits,
            cfg.x_bits,
            cfg.ps_bits,
            &mut lr,
        );
        let pert =
            CrossbarPerturbation::sample(rows, cols * cfg.w_bits as usize, ni, &mut lr);
        let ideal = psq_mvm_scalar(&w, &x, &params);
        let actual = psq_mvm_nonideal_scalar(&w, &x, &params, &pert);
        layers.push(LayerOutcome::compare(ann.index, &ideal, &actual));
    }
    TrialOutcome {
        seed,
        layers,
        ps_full_scale: (1i64 << (cfg.ps_bits - 1)) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::quant::psq::{psq_mvm, PsqMode};
    use crate::util::prop::{check, Gen};

    fn small_cfg() -> HcimConfig {
        let mut cfg = HcimConfig::config_a();
        cfg.xbar.rows = 32;
        cfg.xbar.cols = 32;
        cfg
    }

    fn rand_problem(g: &mut Gen, w_bits: u32) -> (Mat, Vec<i64>) {
        let rows = g.len(24).max(2);
        let cols = g.len(6).max(1);
        let lo = -(1i64 << (w_bits - 1));
        let hi = (1i64 << (w_bits - 1)) - 1;
        let w = Mat { rows, cols, data: g.vec_i64(rows * cols, lo, hi) };
        let x = g.vec_i64(rows, 0, 15);
        (w, x)
    }

    #[test]
    fn identity_perturbation_is_bit_exact() {
        check("identity perturbation == ideal PSQ path", 40, |g: &mut Gen| {
            let (w, x) = rand_problem(g, 4);
            let mut rng = Rng::new(g.seed ^ 0xA5);
            let mode = if g.bool(0.5) {
                PsqMode::Ternary { alpha: 2.0 }
            } else {
                PsqMode::Binary
            };
            let params = PsqLayerParams::calibrated(&w, mode, 4, 4, 8, &mut rng);
            let pert = CrossbarPerturbation::identity(w.rows, w.cols * 4);
            let ideal = psq_mvm(&w, &x, &params);
            let actual = psq_mvm_nonideal(&w, &x, &params, &pert);
            assert_eq!(ideal.p, actual.p, "codes must match bit-for-bit");
            assert_eq!(ideal.ps, actual.ps, "partial sums must match");
            let out = LayerOutcome::compare(0, &ideal, &actual);
            assert_eq!(out.flips, 0);
            assert_eq!(out.zero_corruptions, 0);
            assert_eq!(out.ps_l1, 0.0);
        });
    }

    fn rand_problem_rng(rng: &mut Rng, rows: usize, cols: usize, w_bits: u32) -> (Mat, Vec<i64>) {
        let lo = -(1i64 << (w_bits - 1));
        let hi = (1i64 << (w_bits - 1)) - 1;
        let w = Mat::from_fn(rows, cols, |_, _| rng.range_i64(lo, hi));
        let x = (0..rows).map(|_| rng.range_i64(0, 15)).collect();
        (w, x)
    }

    #[test]
    fn sampled_ideal_params_are_also_bit_exact() {
        // sample() with all-zero magnitudes must behave like identity()
        let mut rng = Rng::new(42);
        let (w, x) = rand_problem_rng(&mut rng, 20, 5, 4);
        let params =
            PsqLayerParams::calibrated(&w, PsqMode::Ternary { alpha: 2.0 }, 4, 4, 8, &mut rng);
        let pert = CrossbarPerturbation::sample(
            w.rows,
            w.cols * 4,
            &NonIdealityParams::ideal(),
            &mut rng,
        );
        let ideal = psq_mvm(&w, &x, &params);
        let actual = psq_mvm_nonideal(&w, &x, &params, &pert);
        assert_eq!(ideal.p, actual.p);
        assert_eq!(ideal.ps, actual.ps);
    }

    #[test]
    fn all_cells_stuck_off_silence_every_column() {
        let w = Mat::from_fn(8, 2, |r, c| ((r + c) as i64 % 15) - 7);
        let mut rng = Rng::new(5);
        let params =
            PsqLayerParams::calibrated(&w, PsqMode::Binary, 4, 2, 8, &mut rng);
        let ni = NonIdealityParams { stuck_off: 1.0, ..NonIdealityParams::ideal() };
        let pert = CrossbarPerturbation::sample(8, 8, &ni, &mut rng);
        let out = psq_mvm_nonideal(&w, &x_ones(8), &params, &pert);
        assert!(out.analog.iter().all(|&a| a == 0.0), "stuck-off array conducts nothing");
        // binary comparator sees 0 − θ < 0 everywhere → all −1
        assert!(out.p.iter().all(|&p| p == -1));
    }

    fn x_ones(n: usize) -> Vec<i64> {
        vec![3; n]
    }

    #[test]
    fn strong_variation_flips_codes() {
        let mut rng = Rng::new(17);
        let (w, x) = rand_problem_rng(&mut rng, 24, 6, 4);
        let params =
            PsqLayerParams::calibrated(&w, PsqMode::Ternary { alpha: 1.0 }, 4, 4, 8, &mut rng);
        let ni = NonIdealityParams {
            sigma_g: 0.5,
            sigma_cmp: 2.0,
            ..NonIdealityParams::ideal()
        };
        let pert = CrossbarPerturbation::sample(w.rows, w.cols * 4, &ni, &mut rng);
        let ideal = psq_mvm(&w, &x, &params);
        let actual = psq_mvm_nonideal(&w, &x, &params, &pert);
        let out = LayerOutcome::compare(0, &ideal, &actual);
        assert!(out.flips > 0, "σ_G = 0.5 + σ_cmp = 2 LSB must flip codes");
    }

    #[test]
    fn trial_covers_every_mvm_layer_and_is_deterministic() {
        let g = zoo::resnet20();
        let cfg = small_cfg();
        let ni = NonIdealityParams::default_for(cfg.node);
        let a = run_trial(&g, &cfg, &ni, 99);
        let b = run_trial(&g, &cfg, &ni, 99);
        assert_eq!(a, b, "same seed, same outcome");
        let mvm_layers = g.annotate().iter().filter(|ann| ann.mvm.is_some()).count();
        assert_eq!(a.layers.len(), mvm_layers);
        assert!(a.flip_rate() > 0.0, "default 32 nm magnitudes perturb something");
        let c = run_trial(&g, &cfg, &ni, 100);
        assert_ne!(a, c, "different seed, different outcome");
    }

    #[test]
    fn ideal_trial_has_exactly_zero_flip_rate() {
        let g = zoo::vgg9();
        let cfg = small_cfg();
        let t = run_trial(&g, &cfg, &NonIdealityParams::ideal(), 7);
        assert_eq!(t.flip_rate(), 0.0, "ideal path must be exact, not approximate");
        assert_eq!(t.zero_corruption_rate(), 0.0);
        assert_eq!(t.disagreement(), 0.0);
    }

    #[test]
    fn binary_mode_has_no_zero_codes_to_corrupt() {
        let g = zoo::resnet20();
        let cfg = small_cfg().binary();
        let ni = NonIdealityParams::default_for(cfg.node);
        let t = run_trial(&g, &cfg, &ni, 13);
        let zeros: usize = t.layers.iter().map(|l| l.ideal_zeros).sum();
        assert_eq!(zeros, 0);
        assert_eq!(t.zero_corruption_rate(), 0.0);
    }

    // ---- packed engine ⇄ scalar oracle equivalence -----------------------

    fn assert_nonideal_identical(a: &NonIdealOutput, b: &NonIdealOutput, ctx: &str) {
        assert_eq!(a.p, b.p, "{ctx}: comparator codes diverge");
        assert_eq!(a.ps, b.ps, "{ctx}: partial sums diverge");
        // f64 equality is intentional: the packed path must reproduce the
        // scalar summation order exactly, not approximately
        assert_eq!(a.analog, b.analog, "{ctx}: analog sums diverge");
    }

    #[test]
    fn packed_nonideal_matches_scalar_oracle_under_perturbation() {
        check("psq_mvm_nonideal (packed) == scalar oracle", 80, |g: &mut Gen| {
            let rows = g.usize(1, 300);
            let cols = g.usize(1, 3);
            let w_bits = g.usize(1, 8) as u32;
            let x_bits = g.usize(1, 8) as u32;
            let mode = if g.bool(0.5) {
                PsqMode::Binary
            } else {
                PsqMode::Ternary { alpha: g.f64(0.0, 4.0) }
            };
            let lo = -(1i64 << (w_bits - 1));
            let hi = (1i64 << (w_bits - 1)) - 1;
            let w = Mat { rows, cols, data: g.vec_i64(rows * cols, lo, hi) };
            let x = g.vec_i64(rows, 0, (1i64 << x_bits) - 1);
            let mut rng = Rng::new(g.seed ^ 0x51CE);
            let params =
                PsqLayerParams::calibrated(&w, mode, w_bits, x_bits, 8, &mut rng);
            // non-trivial magnitudes: every perturbation source active
            let ni = NonIdealityParams {
                sigma_g: g.f64(0.0, 0.4),
                stuck_on: g.f64(0.0, 0.05),
                stuck_off: g.f64(0.0, 0.05),
                ir_drop: g.f64(0.0, 0.2),
                sigma_cmp: g.f64(0.0, 1.5),
            };
            let pert =
                CrossbarPerturbation::sample(rows, cols * w_bits as usize, &ni, &mut rng);
            assert_nonideal_identical(
                &psq_mvm_nonideal(&w, &x, &params, &pert),
                &psq_mvm_nonideal_scalar(&w, &x, &params, &pert),
                "sampled perturbation",
            );
            // and under the exact identity
            let id = CrossbarPerturbation::identity(rows, cols * w_bits as usize);
            assert_nonideal_identical(
                &psq_mvm_nonideal(&w, &x, &params, &id),
                &psq_mvm_nonideal_scalar(&w, &x, &params, &id),
                "identity perturbation",
            );
        });
    }

    #[test]
    fn nonideal_engine_is_reusable_across_inputs() {
        let mut rng = Rng::new(31);
        let (w, _) = rand_problem_rng(&mut rng, 130, 3, 4);
        let params =
            PsqLayerParams::calibrated(&w, PsqMode::Ternary { alpha: 1.0 }, 4, 4, 8, &mut rng);
        let ni = NonIdealityParams {
            sigma_g: 0.2,
            stuck_on: 0.02,
            stuck_off: 0.02,
            ir_drop: 0.1,
            sigma_cmp: 0.5,
        };
        let pert = CrossbarPerturbation::sample(130, 12, &ni, &mut rng);
        let mut engine = NonIdealEngine::program(&w, &params, &pert);
        let mut out = NonIdealOutput::zeroed(0, 0);
        for s in 0..6u64 {
            let mut xr = Rng::new(s);
            let x: Vec<i64> = (0..130).map(|_| xr.range_i64(0, 15)).collect();
            engine.mvm_into(&x, &mut out);
            assert_nonideal_identical(
                &out,
                &psq_mvm_nonideal_scalar(&w, &x, &params, &pert),
                "engine reuse",
            );
        }
    }

    #[test]
    fn run_trial_matches_scalar_trial_bit_for_bit() {
        let g = zoo::resnet20();
        let cfg = small_cfg();
        let ni = NonIdealityParams::default_for(cfg.node);
        for seed in [0u64, 1, 99, 0xC0FFEE] {
            assert_eq!(
                run_trial(&g, &cfg, &ni, seed),
                run_trial_scalar(&g, &cfg, &ni, seed),
                "trial outcome must be byte-identical at seed {seed}"
            );
        }
    }
}
