//! Injection of non-idealities into the functional PSQ path.
//!
//! [`psq_mvm_nonideal`] mirrors [`crate::quant::psq::psq_mvm`] bit-step by
//! bit-step but perturbs the *analog* partial sum between the crossbar
//! popcount ([`crate::quant::bits::bit_dot`]'s role) and the comparator
//! decision: weight bit-slices are stuck-at-fault masked, each conducting
//! cell contributes its perturbed current (log-normal conductance ×
//! IR-drop attenuation), and the decision runs through a real
//! [`ComparatorBank`] with per-column input-referred offsets. Everything
//! downstream (scale factors, saturating PS accumulation) is the ideal
//! digital path — HCiM's DCiM array is digital and assumed correct.
//!
//! [`run_trial`] applies this layer-by-layer to a [`crate::model::zoo`]
//! graph: for every MVM layer it synthesizes a representative
//! crossbar-sized problem from a forked per-layer generator, runs the
//! ideal and the perturbed path on identical inputs, and counts PSQ-code
//! flips, ternary zero-code corruptions, and partial-sum disagreement.

use crate::config::hardware::HcimConfig;
use crate::model::graph::Graph;
use crate::nonideal::models::{CrossbarPerturbation, NonIdealityParams};
use crate::quant::bits::{input_bitplane, weight_bitslice, Mat};
use crate::quant::fixed::sat_add;
use crate::quant::psq::{psq_mvm, PsqLayerParams, PsqOutput};
use crate::sim::components::comparator::ComparatorBank;
use crate::util::rng::Rng;

/// Output of one perturbed PSQ-MVM (same layout as
/// [`crate::quant::psq::PsqOutput`], with the analog pre-comparator values
/// kept as floats).
#[derive(Clone, Debug)]
pub struct NonIdealOutput {
    /// Final per-physical-column partial sums.
    pub ps: Vec<i64>,
    /// Comparator codes, `[x_bits × phys_cols]` row-major.
    pub p: Vec<i8>,
    /// Perturbed analog column values, same layout.
    pub analog: Vec<f64>,
}

/// Perturbed PSQ matrix-vector product over one crossbar.
///
/// With `pert` the exact identity this is code- and PS-identical to
/// [`psq_mvm`] (the analog value of a column is then the integer popcount,
/// exactly representable in `f64`).
pub fn psq_mvm_nonideal(
    w: &Mat,
    x: &[i64],
    params: &PsqLayerParams,
    pert: &CrossbarPerturbation,
) -> NonIdealOutput {
    assert_eq!(w.rows, x.len(), "input/crossbar row mismatch");
    let phys_cols = w.cols * params.w_bits as usize;
    assert_eq!(pert.rows, w.rows, "perturbation row mismatch");
    assert_eq!(pert.phys_cols, phys_cols, "perturbation column mismatch");
    assert_eq!(
        params.scales.len(),
        params.x_bits as usize * phys_cols,
        "scale factor table shape mismatch"
    );

    // program the crossbar: bit-sliced columns with stuck-at faults applied
    let mut colbits: Vec<Vec<u8>> = Vec::with_capacity(phys_cols);
    for lc in 0..w.cols {
        let col = w.col(lc);
        for i in 0..params.w_bits {
            let c = colbits.len();
            let mut bits = weight_bitslice(&col, i, params.w_bits);
            for (r, b) in bits.iter_mut().enumerate() {
                *b = pert.fault_bit(r, c, *b);
            }
            colbits.push(bits);
        }
    }

    let bank = ComparatorBank::new(params.mode, params.theta, phys_cols);
    let mut ps = vec![0i64; phys_cols];
    let mut p_all = vec![0i8; params.x_bits as usize * phys_cols];
    let mut analog_all = vec![0.0f64; params.x_bits as usize * phys_cols];
    for j in 0..params.x_bits {
        let xp = input_bitplane(x, j);
        let analog: Vec<f64> = (0..phys_cols)
            .map(|c| {
                let mut a = 0.0;
                for (r, (&wb, &xb)) in colbits[c].iter().zip(xp.iter()).enumerate() {
                    if (wb & xb) == 1 {
                        a += pert.cell_gain(r, c);
                    }
                }
                a
            })
            .collect();
        let codes = bank.compare_analog(&analog, pert.comparator_offsets());
        for (c, code) in codes.iter().enumerate() {
            let idx = j as usize * phys_cols + c;
            analog_all[idx] = analog[c];
            let p = code.decode();
            p_all[idx] = p;
            if p != 0 {
                ps[c] = sat_add(ps[c], p as i64 * params.scales[idx], params.ps_bits);
            }
        }
    }
    NonIdealOutput { ps, p: p_all, analog: analog_all }
}

/// Ideal-vs-perturbed comparison for one MVM layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerOutcome {
    /// Index of the layer in the graph's layer list.
    pub layer_index: usize,
    /// Comparator decisions compared (`x_bits × phys_cols`).
    pub codes: usize,
    /// Decisions whose PSQ code changed under perturbation.
    pub flips: usize,
    /// Ideal-path zero codes (the sparsity the DCiM gating exploits).
    pub ideal_zeros: usize,
    /// Ideal zeros that became non-zero — lost gating opportunities.
    pub zero_corruptions: usize,
    /// Physical columns compared.
    pub columns: usize,
    /// Σ|PS_ideal − PS_perturbed| over the columns.
    pub ps_l1: f64,
}

impl LayerOutcome {
    /// Compare the ideal and perturbed outputs of one crossbar MVM.
    pub fn compare(layer_index: usize, ideal: &PsqOutput, actual: &NonIdealOutput) -> LayerOutcome {
        assert_eq!(ideal.p.len(), actual.p.len());
        assert_eq!(ideal.ps.len(), actual.ps.len());
        let mut flips = 0;
        let mut ideal_zeros = 0;
        let mut zero_corruptions = 0;
        for (&pi, &pa) in ideal.p.iter().zip(&actual.p) {
            if pi != pa {
                flips += 1;
            }
            if pi == 0 {
                ideal_zeros += 1;
                if pa != 0 {
                    zero_corruptions += 1;
                }
            }
        }
        let ps_l1 = ideal
            .ps
            .iter()
            .zip(&actual.ps)
            .map(|(&a, &b)| (a - b).abs() as f64)
            .sum();
        LayerOutcome {
            layer_index,
            codes: ideal.p.len(),
            flips,
            ideal_zeros,
            zero_corruptions,
            columns: ideal.ps.len(),
            ps_l1,
        }
    }
}

/// One full Monte Carlo trial: every MVM layer of a model compared
/// ideal-vs-perturbed under a single seed.
#[derive(Clone, Debug, PartialEq)]
pub struct TrialOutcome {
    pub seed: u64,
    pub layers: Vec<LayerOutcome>,
    /// Full-scale magnitude of the PS register (`2^{ps_bits−1}`), the
    /// normalizer of [`TrialOutcome::disagreement`].
    pub ps_full_scale: f64,
}

impl TrialOutcome {
    /// Fraction of comparator decisions whose PSQ code flipped.
    pub fn flip_rate(&self) -> f64 {
        let codes: usize = self.layers.iter().map(|l| l.codes).sum();
        let flips: usize = self.layers.iter().map(|l| l.flips).sum();
        if codes == 0 { 0.0 } else { flips as f64 / codes as f64 }
    }

    /// Fraction of ideal ternary zero codes corrupted to ±1 (0 when the
    /// ideal path produced no zeros, e.g. binary PSQ).
    pub fn zero_corruption_rate(&self) -> f64 {
        let zeros: usize = self.layers.iter().map(|l| l.ideal_zeros).sum();
        let corrupted: usize = self.layers.iter().map(|l| l.zero_corruptions).sum();
        if zeros == 0 { 0.0 } else { corrupted as f64 / zeros as f64 }
    }

    /// End-to-end code-disagreement score: mean |ΔPS| per column,
    /// normalized by the PS register full scale (0 = bit-identical,
    /// 1 ≈ every column off by the whole register range).
    pub fn disagreement(&self) -> f64 {
        let cols: usize = self.layers.iter().map(|l| l.columns).sum();
        let l1: f64 = self.layers.iter().map(|l| l.ps_l1).sum();
        if cols == 0 { 0.0 } else { l1 / (cols as f64 * self.ps_full_scale) }
    }
}

/// Run one trial of `graph` on the PSQ periphery of `cfg` under `ni`.
///
/// Per-layer state (synthetic weights/activations in the config's code
/// ranges, calibrated PSQ parameters, and the sampled perturbation) comes
/// from a generator forked off the trial seed in layer order — fully
/// deterministic, and independent across trials by construction.
pub fn run_trial(
    graph: &Graph,
    cfg: &HcimConfig,
    ni: &NonIdealityParams,
    seed: u64,
) -> TrialOutcome {
    let mut rng = Rng::new(seed);
    let w_lo = -(1i64 << (cfg.w_bits - 1));
    let w_hi = (1i64 << (cfg.w_bits - 1)) - 1;
    let x_hi = (1i64 << cfg.x_bits) - 1;
    let mut layers = Vec::new();
    for ann in graph.annotate() {
        let Some(mvm) = ann.mvm else { continue };
        let mut lr = rng.fork();
        // one representative crossbar tile of the layer's mapping
        let rows = mvm.rows.min(cfg.xbar.rows).max(1);
        let max_logical = (cfg.xbar.cols / cfg.w_bits as usize).max(1);
        let cols = mvm.cols.min(max_logical).max(1);
        let w = Mat::from_fn(rows, cols, |_, _| lr.range_i64(w_lo, w_hi));
        let x: Vec<i64> = (0..rows).map(|_| lr.range_i64(0, x_hi)).collect();
        let params = PsqLayerParams::calibrated(
            &w,
            cfg.mode,
            cfg.w_bits,
            cfg.x_bits,
            cfg.ps_bits,
            &mut lr,
        );
        let pert =
            CrossbarPerturbation::sample(rows, cols * cfg.w_bits as usize, ni, &mut lr);
        let ideal = psq_mvm(&w, &x, &params);
        let actual = psq_mvm_nonideal(&w, &x, &params, &pert);
        layers.push(LayerOutcome::compare(ann.index, &ideal, &actual));
    }
    TrialOutcome {
        seed,
        layers,
        ps_full_scale: (1i64 << (cfg.ps_bits - 1)) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::quant::psq::PsqMode;
    use crate::util::prop::{check, Gen};

    fn small_cfg() -> HcimConfig {
        let mut cfg = HcimConfig::config_a();
        cfg.xbar.rows = 32;
        cfg.xbar.cols = 32;
        cfg
    }

    fn rand_problem(g: &mut Gen, w_bits: u32) -> (Mat, Vec<i64>) {
        let rows = g.len(24).max(2);
        let cols = g.len(6).max(1);
        let lo = -(1i64 << (w_bits - 1));
        let hi = (1i64 << (w_bits - 1)) - 1;
        let w = Mat { rows, cols, data: g.vec_i64(rows * cols, lo, hi) };
        let x = g.vec_i64(rows, 0, 15);
        (w, x)
    }

    #[test]
    fn identity_perturbation_is_bit_exact() {
        check("identity perturbation == ideal PSQ path", 40, |g: &mut Gen| {
            let (w, x) = rand_problem(g, 4);
            let mut rng = Rng::new(g.seed ^ 0xA5);
            let mode = if g.bool(0.5) {
                PsqMode::Ternary { alpha: 2.0 }
            } else {
                PsqMode::Binary
            };
            let params = PsqLayerParams::calibrated(&w, mode, 4, 4, 8, &mut rng);
            let pert = CrossbarPerturbation::identity(w.rows, w.cols * 4);
            let ideal = psq_mvm(&w, &x, &params);
            let actual = psq_mvm_nonideal(&w, &x, &params, &pert);
            assert_eq!(ideal.p, actual.p, "codes must match bit-for-bit");
            assert_eq!(ideal.ps, actual.ps, "partial sums must match");
            let out = LayerOutcome::compare(0, &ideal, &actual);
            assert_eq!(out.flips, 0);
            assert_eq!(out.zero_corruptions, 0);
            assert_eq!(out.ps_l1, 0.0);
        });
    }

    fn rand_problem_rng(rng: &mut Rng, rows: usize, cols: usize, w_bits: u32) -> (Mat, Vec<i64>) {
        let lo = -(1i64 << (w_bits - 1));
        let hi = (1i64 << (w_bits - 1)) - 1;
        let w = Mat::from_fn(rows, cols, |_, _| rng.range_i64(lo, hi));
        let x = (0..rows).map(|_| rng.range_i64(0, 15)).collect();
        (w, x)
    }

    #[test]
    fn sampled_ideal_params_are_also_bit_exact() {
        // sample() with all-zero magnitudes must behave like identity()
        let mut rng = Rng::new(42);
        let (w, x) = rand_problem_rng(&mut rng, 20, 5, 4);
        let params =
            PsqLayerParams::calibrated(&w, PsqMode::Ternary { alpha: 2.0 }, 4, 4, 8, &mut rng);
        let pert = CrossbarPerturbation::sample(
            w.rows,
            w.cols * 4,
            &NonIdealityParams::ideal(),
            &mut rng,
        );
        let ideal = psq_mvm(&w, &x, &params);
        let actual = psq_mvm_nonideal(&w, &x, &params, &pert);
        assert_eq!(ideal.p, actual.p);
        assert_eq!(ideal.ps, actual.ps);
    }

    #[test]
    fn all_cells_stuck_off_silence_every_column() {
        let w = Mat::from_fn(8, 2, |r, c| ((r + c) as i64 % 15) - 7);
        let mut rng = Rng::new(5);
        let params =
            PsqLayerParams::calibrated(&w, PsqMode::Binary, 4, 2, 8, &mut rng);
        let ni = NonIdealityParams { stuck_off: 1.0, ..NonIdealityParams::ideal() };
        let pert = CrossbarPerturbation::sample(8, 8, &ni, &mut rng);
        let out = psq_mvm_nonideal(&w, &x_ones(8), &params, &pert);
        assert!(out.analog.iter().all(|&a| a == 0.0), "stuck-off array conducts nothing");
        // binary comparator sees 0 − θ < 0 everywhere → all −1
        assert!(out.p.iter().all(|&p| p == -1));
    }

    fn x_ones(n: usize) -> Vec<i64> {
        vec![3; n]
    }

    #[test]
    fn strong_variation_flips_codes() {
        let mut rng = Rng::new(17);
        let (w, x) = rand_problem_rng(&mut rng, 24, 6, 4);
        let params =
            PsqLayerParams::calibrated(&w, PsqMode::Ternary { alpha: 1.0 }, 4, 4, 8, &mut rng);
        let ni = NonIdealityParams {
            sigma_g: 0.5,
            sigma_cmp: 2.0,
            ..NonIdealityParams::ideal()
        };
        let pert = CrossbarPerturbation::sample(w.rows, w.cols * 4, &ni, &mut rng);
        let ideal = psq_mvm(&w, &x, &params);
        let actual = psq_mvm_nonideal(&w, &x, &params, &pert);
        let out = LayerOutcome::compare(0, &ideal, &actual);
        assert!(out.flips > 0, "σ_G = 0.5 + σ_cmp = 2 LSB must flip codes");
    }

    #[test]
    fn trial_covers_every_mvm_layer_and_is_deterministic() {
        let g = zoo::resnet20();
        let cfg = small_cfg();
        let ni = NonIdealityParams::default_for(cfg.node);
        let a = run_trial(&g, &cfg, &ni, 99);
        let b = run_trial(&g, &cfg, &ni, 99);
        assert_eq!(a, b, "same seed, same outcome");
        let mvm_layers = g.annotate().iter().filter(|ann| ann.mvm.is_some()).count();
        assert_eq!(a.layers.len(), mvm_layers);
        assert!(a.flip_rate() > 0.0, "default 32 nm magnitudes perturb something");
        let c = run_trial(&g, &cfg, &ni, 100);
        assert_ne!(a, c, "different seed, different outcome");
    }

    #[test]
    fn ideal_trial_has_exactly_zero_flip_rate() {
        let g = zoo::vgg9();
        let cfg = small_cfg();
        let t = run_trial(&g, &cfg, &NonIdealityParams::ideal(), 7);
        assert_eq!(t.flip_rate(), 0.0, "ideal path must be exact, not approximate");
        assert_eq!(t.zero_corruption_rate(), 0.0);
        assert_eq!(t.disagreement(), 0.0);
    }

    #[test]
    fn binary_mode_has_no_zero_codes_to_corrupt() {
        let g = zoo::resnet20();
        let cfg = small_cfg().binary();
        let ni = NonIdealityParams::default_for(cfg.node);
        let t = run_trial(&g, &cfg, &ni, 13);
        let zeros: usize = t.layers.iter().map(|l| l.ideal_zeros).sum();
        assert_eq!(zeros, 0);
        assert_eq!(t.zero_corruption_rate(), 0.0);
    }
}
