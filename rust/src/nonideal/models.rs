//! Composable, seed-deterministic analog non-ideality models.
//!
//! Four perturbation sources, each independently configurable and scaled
//! per [`TechNode`] (Pelgrom mismatch grows on shrink —
//! [`TechNode::variability_scale`]):
//!
//! * **Conductance variation** — RRAM/8T-cell drive strength follows a
//!   mean-one log-normal `exp(σ_G·N − σ_G²/2)` (the standard device model
//!   used by the RRAM-CiM scalability literature).
//! * **Stuck-at faults** — a cell is stuck at `G_on` (always conducts) or
//!   `G_off` (never conducts) with independent per-cell probability.
//! * **Bitline IR drop** — rows electrically farther from the column
//!   sense point see a linearly growing attenuation of their cell current
//!   (up to `ir_drop` at the last row).
//! * **Comparator offset** — each column comparator carries a Gaussian
//!   input-referred offset `σ_cmp·N` in popcount-LSB units, added to its
//!   decision threshold (paper §4.2's dynamic-bias latch comparator).
//!
//! All sampling flows through [`crate::util::rng::Rng`], so a perturbation
//! is a pure function of its seed. With every magnitude set to `0.0` the
//! sampled perturbation is *exactly* the identity (gain `1.0`, offset
//! `0.0`, no faults) — the ideal-path regression guard the Monte Carlo
//! harness asserts on.

use crate::sim::tech::TechNode;
use crate::util::hash::Fnv1a;
use crate::util::rng::Rng;

/// Magnitudes of the four non-ideality sources.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NonIdealityParams {
    /// Log-normal σ of per-cell conductance (ln-space, mean-corrected).
    pub sigma_g: f64,
    /// Probability a cell is stuck at `G_on` (conducts regardless of the
    /// stored weight bit).
    pub stuck_on: f64,
    /// Probability a cell is stuck at `G_off` (never conducts).
    pub stuck_off: f64,
    /// Fractional bitline attenuation at the electrically farthest row
    /// (linear ramp from ~0 at row 0).
    pub ir_drop: f64,
    /// Gaussian σ of the comparator input-referred offset, in popcount
    /// LSBs.
    pub sigma_cmp: f64,
}

impl NonIdealityParams {
    /// All magnitudes zero — the exact-identity perturbation.
    pub fn ideal() -> NonIdealityParams {
        NonIdealityParams {
            sigma_g: 0.0,
            stuck_on: 0.0,
            stuck_off: 0.0,
            ir_drop: 0.0,
            sigma_cmp: 0.0,
        }
    }

    pub fn is_ideal(&self) -> bool {
        self.sigma_g == 0.0
            && self.stuck_on == 0.0
            && self.stuck_off == 0.0
            && self.ir_drop == 0.0
            && self.sigma_cmp == 0.0
    }

    /// Representative magnitudes at `node`, scaled from 65 nm baselines by
    /// the node's mismatch factor (σ_G ≈ 8 %, σ_cmp ≈ 0.35 LSB and 3 %
    /// far-row IR drop at 65 nm; 0.1 % stuck cells independent of node).
    pub fn default_for(node: TechNode) -> NonIdealityParams {
        let s = node.variability_scale();
        NonIdealityParams {
            sigma_g: 0.08 * s,
            stuck_on: 1e-3,
            stuck_off: 1e-3,
            ir_drop: 0.03 * s,
            sigma_cmp: 0.35 * s,
        }
    }

    /// Reject physically meaningless magnitudes before a run.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.sigma_g >= 0.0 && self.sigma_g.is_finite(),
            "sigma_g must be a finite non-negative number (got {})",
            self.sigma_g
        );
        anyhow::ensure!(
            self.sigma_cmp >= 0.0 && self.sigma_cmp.is_finite(),
            "sigma_cmp must be a finite non-negative number (got {})",
            self.sigma_cmp
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.stuck_on) && (0.0..=1.0).contains(&self.stuck_off),
            "stuck-at rates must lie in [0, 1]"
        );
        anyhow::ensure!(
            self.stuck_on + self.stuck_off <= 1.0,
            "stuck_on + stuck_off must not exceed 1"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.ir_drop),
            "ir_drop must lie in [0, 1] (got {})",
            self.ir_drop
        );
        Ok(())
    }

    /// Content fingerprint (cache keys, report metadata).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv1a::new();
        for v in [self.sigma_g, self.stuck_on, self.stuck_off, self.ir_drop, self.sigma_cmp] {
            h.write(&v.to_bits().to_le_bytes());
        }
        h.finish()
    }
}

/// Manufacturing state of one crossbar cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellFault {
    Healthy,
    /// Conducts regardless of the stored weight bit.
    StuckOn,
    /// Never conducts.
    StuckOff,
}

/// One sampled perturbation instance for a `rows × phys_cols` crossbar:
/// per-cell current gains (conductance × IR-drop attenuation), per-cell
/// fault state, and per-column comparator offsets.
#[derive(Clone, Debug)]
pub struct CrossbarPerturbation {
    pub rows: usize,
    pub phys_cols: usize,
    /// Row-major `rows × phys_cols` cell current gain (1.0 = nominal).
    gain: Vec<f64>,
    /// Row-major `rows × phys_cols` fault map.
    fault: Vec<CellFault>,
    /// Per-physical-column comparator input-referred offset (LSBs).
    cmp_offset: Vec<f64>,
}

impl CrossbarPerturbation {
    /// Sample a perturbation from `rng`. Draw order is fixed (cells
    /// row-major, gain then fault, then per-column offsets), so the result
    /// is a pure function of the generator state.
    pub fn sample(
        rows: usize,
        phys_cols: usize,
        p: &NonIdealityParams,
        rng: &mut Rng,
    ) -> CrossbarPerturbation {
        assert!(rows > 0 && phys_cols > 0, "degenerate crossbar");
        let mut gain = Vec::with_capacity(rows * phys_cols);
        let mut fault = Vec::with_capacity(rows * phys_cols);
        for r in 0..rows {
            // linear IR-drop ramp; exactly 1.0 when ir_drop == 0
            let atten = (1.0 - p.ir_drop * (r as f64 + 1.0) / rows as f64).max(0.0);
            for _c in 0..phys_cols {
                // mean-one log-normal: E[exp(σN − σ²/2)] = 1; exactly 1.0
                // when σ == 0
                let g = (p.sigma_g * rng.normal() - 0.5 * p.sigma_g * p.sigma_g).exp();
                gain.push(atten * g);
                let u = rng.f64();
                fault.push(if u < p.stuck_on {
                    CellFault::StuckOn
                } else if u < p.stuck_on + p.stuck_off {
                    CellFault::StuckOff
                } else {
                    CellFault::Healthy
                });
            }
        }
        let cmp_offset = (0..phys_cols).map(|_| p.sigma_cmp * rng.normal()).collect();
        CrossbarPerturbation { rows, phys_cols, gain, fault, cmp_offset }
    }

    /// The exact-identity perturbation (no rng draw at all).
    pub fn identity(rows: usize, phys_cols: usize) -> CrossbarPerturbation {
        CrossbarPerturbation {
            rows,
            phys_cols,
            gain: vec![1.0; rows * phys_cols],
            fault: vec![CellFault::Healthy; rows * phys_cols],
            cmp_offset: vec![0.0; phys_cols],
        }
    }

    /// Effective current contributed by cell `(r, c)` when it conducts.
    #[inline]
    pub fn cell_gain(&self, r: usize, c: usize) -> f64 {
        self.gain[r * self.phys_cols + c]
    }

    /// Apply the cell's stuck-at fault to its programmed weight bit.
    #[inline]
    pub fn fault_bit(&self, r: usize, c: usize, bit: u8) -> u8 {
        match self.fault[r * self.phys_cols + c] {
            CellFault::Healthy => bit,
            CellFault::StuckOn => 1,
            CellFault::StuckOff => 0,
        }
    }

    /// Cell `(r, c)` conducts regardless of the programmed bit. Used by
    /// the packed engine to precompute per-column OR masks.
    #[inline]
    pub fn is_stuck_on(&self, r: usize, c: usize) -> bool {
        self.fault[r * self.phys_cols + c] == CellFault::StuckOn
    }

    /// Cell `(r, c)` never conducts. Used by the packed engine to
    /// precompute per-column AND-NOT masks.
    #[inline]
    pub fn is_stuck_off(&self, r: usize, c: usize) -> bool {
        self.fault[r * self.phys_cols + c] == CellFault::StuckOff
    }

    /// Per-column comparator offsets (length `phys_cols`).
    pub fn comparator_offsets(&self) -> &[f64] {
        &self.cmp_offset
    }

    /// Number of faulty cells in the map.
    pub fn fault_count(&self) -> usize {
        self.fault.iter().filter(|f| **f != CellFault::Healthy).count()
    }

    /// True when this instance is bit-exactly the identity.
    pub fn is_identity(&self) -> bool {
        self.gain.iter().all(|&g| g == 1.0)
            && self.cmp_offset.iter().all(|&o| o == 0.0)
            && self.fault_count() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_params_sample_exact_identity() {
        // the regression guard: zero magnitudes must produce gain 1.0 and
        // offset 0.0 *exactly*, not approximately
        let mut rng = Rng::new(1234);
        let p = CrossbarPerturbation::sample(64, 32, &NonIdealityParams::ideal(), &mut rng);
        assert!(p.is_identity());
        assert_eq!(p.fault_count(), 0);
        for r in 0..64 {
            for c in 0..32 {
                assert_eq!(p.cell_gain(r, c), 1.0);
                assert_eq!(p.fault_bit(r, c, 1), 1);
                assert_eq!(p.fault_bit(r, c, 0), 0);
            }
        }
        assert!(p.comparator_offsets().iter().all(|&o| o == 0.0));
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let ni = NonIdealityParams::default_for(TechNode::N32);
        let a = CrossbarPerturbation::sample(16, 8, &ni, &mut Rng::new(7));
        let b = CrossbarPerturbation::sample(16, 8, &ni, &mut Rng::new(7));
        assert_eq!(a.gain, b.gain);
        assert_eq!(a.fault, b.fault);
        assert_eq!(a.cmp_offset, b.cmp_offset);
        let c = CrossbarPerturbation::sample(16, 8, &ni, &mut Rng::new(8));
        assert_ne!(a.gain, c.gain);
    }

    #[test]
    fn lognormal_gain_is_mean_one() {
        let ni = NonIdealityParams { sigma_g: 0.2, ..NonIdealityParams::ideal() };
        let p = CrossbarPerturbation::sample(128, 128, &ni, &mut Rng::new(3));
        let mean: f64 = p.gain.iter().sum::<f64>() / p.gain.len() as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean gain = {mean}");
        assert!(p.gain.iter().all(|&g| g > 0.0));
    }

    #[test]
    fn stuck_rates_roughly_respected() {
        let ni = NonIdealityParams {
            stuck_on: 0.05,
            stuck_off: 0.10,
            ..NonIdealityParams::ideal()
        };
        let p = CrossbarPerturbation::sample(128, 128, &ni, &mut Rng::new(5));
        let on = p.fault.iter().filter(|f| **f == CellFault::StuckOn).count();
        let off = p.fault.iter().filter(|f| **f == CellFault::StuckOff).count();
        let n = p.fault.len() as f64;
        assert!((on as f64 / n - 0.05).abs() < 0.01, "on rate {}", on as f64 / n);
        assert!((off as f64 / n - 0.10).abs() < 0.01, "off rate {}", off as f64 / n);
    }

    #[test]
    fn ir_drop_attenuates_far_rows_monotonically() {
        let ni = NonIdealityParams { ir_drop: 0.2, ..NonIdealityParams::ideal() };
        let p = CrossbarPerturbation::sample(100, 4, &ni, &mut Rng::new(9));
        // with sigma_g = 0 the gain is pure attenuation: strictly decreasing
        for r in 1..100 {
            assert!(p.cell_gain(r, 0) < p.cell_gain(r - 1, 0));
        }
        assert!((p.cell_gain(99, 0) - 0.8).abs() < 1e-12, "far row keeps 1 − ir_drop");
    }

    #[test]
    fn node_scaling_orders_magnitudes() {
        let n65 = NonIdealityParams::default_for(TechNode::N65);
        let n22 = NonIdealityParams::default_for(TechNode::N22);
        assert!(n22.sigma_g > n65.sigma_g);
        assert!(n22.sigma_cmp > n65.sigma_cmp);
        assert!(n22.ir_drop > n65.ir_drop);
        assert_eq!(n22.stuck_on, n65.stuck_on);
        assert!(n65.validate().is_ok());
        assert!(n22.validate().is_ok());
    }

    #[test]
    fn validate_rejects_nonsense() {
        let mut p = NonIdealityParams::ideal();
        assert!(p.validate().is_ok());
        assert!(NonIdealityParams { sigma_g: -0.1, ..p }.validate().is_err());
        assert!(NonIdealityParams { ir_drop: 1.5, ..p }.validate().is_err());
        assert!(NonIdealityParams { stuck_on: -0.01, ..p }.validate().is_err());
        p.stuck_on = 0.7;
        p.stuck_off = 0.7;
        assert!(p.validate().is_err(), "rates summing past 1 must be rejected");
    }

    #[test]
    fn fingerprint_tracks_content() {
        let a = NonIdealityParams::default_for(TechNode::N32);
        let b = NonIdealityParams::default_for(TechNode::N32);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = NonIdealityParams { sigma_g: a.sigma_g + 0.01, ..a };
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(a.fingerprint(), NonIdealityParams::ideal().fingerprint());
    }

    #[test]
    fn stuck_accessors_agree_with_fault_bit() {
        let ni = NonIdealityParams {
            stuck_on: 0.1,
            stuck_off: 0.1,
            ..NonIdealityParams::ideal()
        };
        let p = CrossbarPerturbation::sample(32, 16, &ni, &mut Rng::new(11));
        for r in 0..32 {
            for c in 0..16 {
                assert_eq!(p.is_stuck_on(r, c), p.fault_bit(r, c, 0) == 1);
                assert_eq!(p.is_stuck_off(r, c), p.fault_bit(r, c, 1) == 0);
                assert!(!(p.is_stuck_on(r, c) && p.is_stuck_off(r, c)));
            }
        }
        assert!(p.fault_count() > 0, "10 %+10 % rates over 512 cells must fault some");
    }

    #[test]
    fn ideal_flag_consistency() {
        assert!(NonIdealityParams::ideal().is_ideal());
        assert!(!NonIdealityParams::default_for(TechNode::N65).is_ideal());
        assert!(CrossbarPerturbation::identity(4, 4).is_identity());
    }
}
