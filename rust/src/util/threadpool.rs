//! Fixed-size worker thread pool.
//!
//! Offline stand-in for `rayon`/`tokio`: a small, dependency-free pool used
//! by the serving coordinator (request execution) and the experiment sweeps
//! (parallel simulator runs). Work items are boxed closures delivered over
//! an `mpsc` channel guarded by a mutex (multi-consumer).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
            let in_flight = Arc::clone(&in_flight);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hcim-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::AcqRel);
                            }
                            Err(_) => break, // sender dropped → shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Pool sized to the machine (cores, min 2).
    pub fn default_size() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(n.max(2))
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Busy-wait (with yield) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let (done_tx, done_rx) = channel::<()>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let done_tx = done_tx.clone();
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
                let _ = done_tx.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv().expect("worker died mid-map");
        }
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("missing map result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channel makes workers exit after draining.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn map_empty_is_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }
}
