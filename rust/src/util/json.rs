//! Minimal JSON parser + serializer (offline stand-in for `serde_json`).
//!
//! Used for the artifact interchange files written by the python build path:
//! `artifacts/manifest.json`, `artifacts/sparsity.json`,
//! `artifacts/accuracy.json`. Supports the full JSON grammar except for
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII
//! manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `obj.get(key)` as f64 with error context.
    pub fn num_field(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| JsonError(format!("missing/invalid numeric field '{key}'")))
    }

    pub fn str_field(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| JsonError(format!("missing/invalid string field '{key}'")))
    }
}

/// Fixed 3-decimal rounding before serialization, shared by the
/// deterministic report writers (serving metrics, timeline): derived
/// floats (percentiles, rates, utilizations) print byte-stably and stay
/// hand-checkable in the golden files.
pub fn num3(x: f64) -> Json {
    Json::Num((x * 1000.0).round() / 1000.0)
}

/// Parse / access error.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for ch in s.chars() {
                    match ch {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].str_field("b").unwrap(),
            "c"
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-1}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn field_helpers_report_errors() {
        let j = Json::parse(r#"{"n": "not-a-number"}"#).unwrap();
        assert!(j.num_field("n").is_err());
        assert!(j.num_field("missing").is_err());
        assert_eq!(j.str_field("n").unwrap(), "not-a-number");
    }
}
