//! Infrastructure substrates.
//!
//! This environment is fully offline, so the usual ecosystem crates
//! (`rand`, `proptest`, `criterion`, `rayon`, `serde_json`, …) are not
//! available. Everything the rest of the crate needs is implemented here:
//!
//! * [`rng`] — deterministic SplitMix64 / xoshiro256++ PRNG,
//! * [`prop`] — a miniature property-based testing harness,
//! * [`stats`] — descriptive statistics and percentile helpers,
//! * [`table`] — ASCII table rendering for bench/experiment output,
//! * [`threadpool`] — scoped worker pool used by the coordinator and the
//!   parameter sweeps,
//! * [`bench`] — a criterion-flavoured timing harness for `cargo bench`,
//! * [`json`] — a minimal JSON parser/serializer for artifact manifests,
//! * [`hash`] — FNV-1a hashing for cache keys and fingerprints,
//! * [`logging`] — leveled stderr logger.

pub mod rng;
pub mod hash;
pub mod prop;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod bench;
pub mod json;
pub mod logging;
