//! Miniature property-based testing harness (offline stand-in for
//! `proptest`).
//!
//! A property is a closure over a [`Gen`]; [`check`] runs it for a number of
//! seeded cases and, on failure, retries with a halved "size" parameter to
//! give crude shrinking, then panics with the offending seed so the case is
//! reproducible:
//!
//! ```no_run
//! use hcim::util::prop::{check, Gen};
//! check("addition commutes", 256, |g: &mut Gen| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! (`no_run`: doctest binaries are built outside the workspace rpath and
//! cannot locate libstdc++ in this offline image; the same behaviour is
//! covered by the unit tests below.)

use super::rng::Rng;

/// Per-case generator handed to properties. Wraps a seeded [`Rng`] plus a
/// size hint that decays during shrink attempts.
pub struct Gen {
    rng: Rng,
    /// Soft upper bound on generated structure sizes (vectors, matrices).
    pub size: usize,
    /// Seed of this case, for error reporting.
    pub seed: u64,
}

impl Gen {
    fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Rng::new(seed), size, seed }
    }

    /// Integer in `[lo, hi]` inclusive.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    /// `usize` in `[lo, hi]` inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }

    /// Float in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Length bounded by the current shrink size.
    pub fn len(&mut self, max: usize) -> usize {
        let cap = max.min(self.size.max(1));
        self.usize(1, cap.max(1))
    }

    /// Vector of `n` draws.
    pub fn vec_i64(&mut self, n: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..n).map(|_| self.i64(lo, hi)).collect()
    }

    /// Vector of `n` float draws.
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    /// Choose uniformly among `items`.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.choose(items)
    }

    /// Access the raw RNG for bespoke distributions.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Default number of cases used by most property tests in this crate.
pub const DEFAULT_CASES: u32 = 200;

/// Run `prop` for `cases` seeded cases. Panics (with seed + shrink info) on
/// the first failing case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u32, prop: F) {
    // Base seed mixes the property name so distinct properties explore
    // distinct corners even with identical case indices.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for case in 0..cases {
        let seed = h ^ ((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let initial_size = 2 + (case as usize % 64);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, initial_size);
            prop(&mut g);
        });
        if let Err(payload) = result {
            // Crude shrink: retry the same seed with smaller sizes and
            // report the smallest size that still fails.
            let mut failing_size = initial_size;
            let mut sz = initial_size / 2;
            while sz >= 1 {
                let r = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, sz);
                    prop(&mut g);
                });
                if r.is_err() {
                    failing_size = sz;
                }
                sz /= 2;
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed: case={case} seed={seed:#x} \
                 min_failing_size={failing_size}\n  cause: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 64, |g| {
            let n = g.len(32);
            let v = g.vec_i64(n, -100, 100);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_reports_seed() {
        check("always fails", 8, |g| {
            let x = g.i64(0, 10);
            assert!(x > 100, "x was {x}");
        });
    }

    #[test]
    fn gen_bounds_respected() {
        check("gen bounds", 128, |g| {
            let x = g.i64(-5, 5);
            assert!((-5..=5).contains(&x));
            let u = g.usize(1, 9);
            assert!((1..=9).contains(&u));
            let f = g.f64(0.5, 2.5);
            assert!((0.5..2.5).contains(&f));
        });
    }
}
