//! Non-cryptographic hashing (FNV-1a 64) shared by the DSE result cache
//! and the sparsity-table fingerprint.
//!
//! One home for the FNV constants so cache keys and fingerprints cannot
//! drift apart. [`Fnv1a`] is the streaming form; use
//! [`Fnv1a::write_delimited`] for variable-length fields so the encoding
//! stays injective (a length prefix prevents `"ab" + "c"` from colliding
//! with `"a" + "bc"`).

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// One-shot FNV-1a 64-bit hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

/// Incremental FNV-1a hasher for multi-field keys.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(FNV_OFFSET)
    }

    /// Mix raw bytes (fixed-width fields).
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    /// Mix a variable-length field with a length prefix, keeping the
    /// overall byte stream an injective encoding of the field sequence.
    pub fn write_delimited(&mut self, bytes: &[u8]) {
        self.write(&(bytes.len() as u64).to_le_bytes());
        self.write(bytes);
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // canonical published FNV-1a 64 values
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut h = Fnv1a::new();
        h.write(b"hello ");
        h.write(b"world");
        assert_eq!(h.finish(), fnv1a64(b"hello world"));
    }

    #[test]
    fn delimited_fields_do_not_collide_on_boundaries() {
        let hash2 = |a: &[u8], b: &[u8]| {
            let mut h = Fnv1a::new();
            h.write_delimited(a);
            h.write_delimited(b);
            h.finish()
        };
        assert_ne!(hash2(b"ab", b"c"), hash2(b"a", b"bc"));
        assert_ne!(hash2(b"", b"x"), hash2(b"x", b""));
    }
}
