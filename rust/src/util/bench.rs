//! Criterion-flavoured micro-bench harness (criterion is unavailable
//! offline). Used by the `harness = false` bench targets.
//!
//! Each benchmark warms up, then runs timed batches until a wall-clock
//! budget is exhausted, and reports mean / p50 / p90 per-iteration times.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;
use super::table::{fnum, Table};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub throughput_per_s: f64,
}

/// Prevent the optimizer from deleting a computed value
/// (stable-Rust `black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // `read_volatile` of a stack copy is the standard trick on stable.
    unsafe {
        let ret = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        ret
    }
}

/// Bench runner with shared settings.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    results: Vec<BenchResult>,
    provenance: Option<String>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(Duration::from_millis(200), Duration::from_millis(1200))
    }
}

impl Bencher {
    pub fn new(warmup: Duration, budget: Duration) -> Bencher {
        Bencher { warmup, budget, results: Vec::new(), provenance: None }
    }

    /// Attach a provenance string (runner, commit, date, kernel flavour)
    /// that [`Bencher::to_json`] emits alongside the results, so
    /// checked-in `BENCH_*.json` artifacts describe where their numbers
    /// came from.
    pub fn set_provenance(&mut self, p: impl Into<String>) {
        self.provenance = Some(p.into());
    }

    /// Fast settings for CI-ish runs (set `HCIM_BENCH_FAST=1`).
    /// `HCIM_BENCH_FAST=0` (or empty) keeps the full-budget defaults —
    /// only a non-empty, non-`"0"` value enables fast mode.
    pub fn from_env() -> Bencher {
        if fast_mode_enabled(std::env::var("HCIM_BENCH_FAST").ok().as_deref()) {
            Bencher::new(Duration::from_millis(30), Duration::from_millis(150))
        } else {
            Bencher::default()
        }
    }

    /// Time `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: figure out how many iters fit in ~5 ms.
        let wstart = Instant::now();
        let mut calib_iters: u64 = 0;
        while wstart.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let batch = ((0.005 / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples_ns: Vec<f64> = Vec::new();
        let mut total_iters: u64 = 0;
        let start = Instant::now();
        while start.elapsed() < self.budget {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(dt);
            total_iters += batch;
        }
        let s = Summary::of(&samples_ns);
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: s.mean,
            p50_ns: s.p50,
            p90_ns: s.p90,
            throughput_per_s: if s.mean > 0.0 { 1e9 / s.mean } else { 0.0 },
        };
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Render all collected results as a table.
    pub fn report(&self) -> String {
        let mut t = Table::new(
            "microbenchmarks",
            &["benchmark", "iters", "mean", "p50", "p90", "ops/s"],
        );
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                r.iters.to_string(),
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p90_ns),
                fnum(r.throughput_per_s),
            ]);
        }
        t.render()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// All collected results as JSON: `{"benchmarks": [{name, iters,
    /// mean_ns, p50_ns, p90_ns, throughput_per_s}, ...]}` — the schema of
    /// the `BENCH_hotpath.json` perf-trajectory artifact.
    pub fn to_json(&self) -> Json {
        let arr = self
            .results
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(r.name.clone()));
                o.insert("iters".into(), Json::Num(r.iters as f64));
                o.insert("mean_ns".into(), Json::Num(r.mean_ns));
                o.insert("p50_ns".into(), Json::Num(r.p50_ns));
                o.insert("p90_ns".into(), Json::Num(r.p90_ns));
                o.insert("throughput_per_s".into(), Json::Num(r.throughput_per_s));
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("benchmarks".into(), Json::Arr(arr));
        if let Some(p) = &self.provenance {
            top.insert("provenance".into(), Json::Str(p.clone()));
        }
        Json::Obj(top)
    }

    /// Write the JSON report to `path` (trailing newline included so the
    /// artifact diffs cleanly when checked in).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// `HCIM_BENCH_FAST` semantics: unset, empty, and the usual falsy
/// spellings (`0`, `false`, `no`, `off`, any case) are OFF; any other
/// value is ON. (A plain `is_ok()` check would treat `=0` as enabled.)
fn fast_mode_enabled(value: Option<&str>) -> bool {
    let Some(v) = value else { return false };
    !v.is_empty() && !matches!(v.to_ascii_lowercase().as_str(), "0" | "false" | "no" | "off")
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut b = Bencher::new(Duration::from_millis(5), Duration::from_millis(20));
        let r = b.bench("noop-ish", || {
            black_box(1u64 + 1);
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.throughput_per_s > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }

    #[test]
    fn black_box_returns_value() {
        assert_eq!(black_box(42), 42);
        assert_eq!(black_box(String::from("x")), "x");
    }

    #[test]
    fn fast_mode_env_semantics() {
        // the regression: `HCIM_BENCH_FAST=0` must NOT enable fast mode,
        // and neither must the other common falsy spellings
        assert!(!fast_mode_enabled(None));
        assert!(!fast_mode_enabled(Some("")));
        assert!(!fast_mode_enabled(Some("0")));
        assert!(!fast_mode_enabled(Some("false")));
        assert!(!fast_mode_enabled(Some("FALSE")));
        assert!(!fast_mode_enabled(Some("no")));
        assert!(!fast_mode_enabled(Some("off")));
        assert!(fast_mode_enabled(Some("1")));
        assert!(fast_mode_enabled(Some("true")));
        assert!(fast_mode_enabled(Some("yes")));
    }

    #[test]
    fn json_report_round_trips() {
        let mut b = Bencher::new(Duration::from_millis(2), Duration::from_millis(8));
        b.bench("alpha", || {
            black_box(3u64 * 7);
        });
        b.bench("beta", || {
            black_box(1u64 + 1);
        });
        let j = Json::parse(&b.to_json().to_string()).expect("self-emitted JSON must parse");
        let benches = j.get("benchmarks").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(benches[0].str_field("name").unwrap(), "alpha");
        assert_eq!(benches[1].str_field("name").unwrap(), "beta");
        for e in benches {
            assert!(e.num_field("iters").unwrap() > 0.0);
            assert!(e.num_field("mean_ns").unwrap() >= 0.0);
            assert!(e.num_field("p50_ns").unwrap() >= 0.0);
            assert!(e.num_field("p90_ns").unwrap() >= 0.0);
            assert!(e.num_field("throughput_per_s").unwrap() > 0.0);
        }
    }

    #[test]
    fn json_report_carries_provenance() {
        let mut b = Bencher::new(Duration::from_millis(2), Duration::from_millis(8));
        b.bench("delta", || {
            black_box(5u64 * 5);
        });
        assert!(b.to_json().get("provenance").is_none(), "absent until set");
        b.set_provenance("runner X · commit Y · date Z");
        let j = Json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(j.str_field("provenance").unwrap(), "runner X · commit Y · date Z");
    }

    #[test]
    fn json_report_writes_to_disk() {
        let mut b = Bencher::new(Duration::from_millis(2), Duration::from_millis(8));
        b.bench("gamma", || {
            black_box(2u64 << 3);
        });
        let path = std::env::temp_dir().join("hcim_bench_json_test.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.ends_with('\n'));
        assert!(Json::parse(body.trim_end()).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}
