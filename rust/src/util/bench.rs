//! Criterion-flavoured micro-bench harness (criterion is unavailable
//! offline). Used by the `harness = false` bench targets.
//!
//! Each benchmark warms up, then runs timed batches until a wall-clock
//! budget is exhausted, and reports mean / p50 / p90 per-iteration times.

use std::time::{Duration, Instant};

use super::stats::Summary;
use super::table::{fnum, Table};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub throughput_per_s: f64,
}

/// Prevent the optimizer from deleting a computed value
/// (stable-Rust `black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // `read_volatile` of a stack copy is the standard trick on stable.
    unsafe {
        let ret = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        ret
    }
}

/// Bench runner with shared settings.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(Duration::from_millis(200), Duration::from_millis(1200))
    }
}

impl Bencher {
    pub fn new(warmup: Duration, budget: Duration) -> Bencher {
        Bencher { warmup, budget, results: Vec::new() }
    }

    /// Fast settings for CI-ish runs (set `HCIM_BENCH_FAST=1`).
    pub fn from_env() -> Bencher {
        if std::env::var("HCIM_BENCH_FAST").is_ok() {
            Bencher::new(Duration::from_millis(30), Duration::from_millis(150))
        } else {
            Bencher::default()
        }
    }

    /// Time `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: figure out how many iters fit in ~5 ms.
        let wstart = Instant::now();
        let mut calib_iters: u64 = 0;
        while wstart.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let batch = ((0.005 / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples_ns: Vec<f64> = Vec::new();
        let mut total_iters: u64 = 0;
        let start = Instant::now();
        while start.elapsed() < self.budget {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(dt);
            total_iters += batch;
        }
        let s = Summary::of(&samples_ns);
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: s.mean,
            p50_ns: s.p50,
            p90_ns: s.p90,
            throughput_per_s: if s.mean > 0.0 { 1e9 / s.mean } else { 0.0 },
        };
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Render all collected results as a table.
    pub fn report(&self) -> String {
        let mut t = Table::new(
            "microbenchmarks",
            &["benchmark", "iters", "mean", "p50", "p90", "ops/s"],
        );
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                r.iters.to_string(),
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p90_ns),
                fnum(r.throughput_per_s),
            ]);
        }
        t.render()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut b = Bencher::new(Duration::from_millis(5), Duration::from_millis(20));
        let r = b.bench("noop-ish", || {
            black_box(1u64 + 1);
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.throughput_per_s > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }

    #[test]
    fn black_box_returns_value() {
        assert_eq!(black_box(42), 42);
        assert_eq!(black_box(String::from("x")), "x");
    }
}
