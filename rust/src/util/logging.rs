//! Leveled stderr logger (offline stand-in for `env_logger`).
//!
//! Level is read once from `HCIM_LOG` (`error|warn|info|debug|trace`,
//! default `info`). Macros mirror the `log` crate's shape.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Inverse of `lvl as u8`; `None` for out-of-range values (including
    /// the `u8::MAX` "uninitialised" sentinel).
    fn from_raw(raw: u8) -> Option<Level> {
        match raw {
            0 => Some(Level::Error),
            1 => Some(Level::Warn),
            2 => Some(Level::Info),
            3 => Some(Level::Debug),
            4 => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<std::time::Instant> = OnceLock::new();

/// Current level (lazily initialised from `HCIM_LOG`).
pub fn level() -> Level {
    if let Some(lvl) = Level::from_raw(LEVEL.load(Ordering::Relaxed)) {
        return lvl;
    }
    let lvl = std::env::var("HCIM_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    // CAS so a racing `set_level` (or a concurrent first call) wins over
    // this lazy env read instead of being clobbered
    match LEVEL.compare_exchange(u8::MAX, lvl as u8, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => lvl,
        Err(raw) => Level::from_raw(raw).unwrap_or(Level::Info),
    }
}

/// Override the level programmatically (CLI `--log-level`).
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Core emit function used by the macros.
pub fn emit(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let t0 = START.get_or_init(std::time::Instant::now);
        let dt = t0.elapsed();
        eprintln!(
            "[{:>9.3}s {} {}] {}",
            dt.as_secs_f64(),
            lvl.tag(),
            module,
            args
        );
    }
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= Level::Debug);
    }

    #[test]
    fn from_raw_inverts_discriminants_and_rejects_garbage() {
        for lvl in [Level::Error, Level::Warn, Level::Info, Level::Debug, Level::Trace] {
            assert_eq!(Level::from_raw(lvl as u8), Some(lvl));
        }
        assert_eq!(Level::from_raw(5), None);
        assert_eq!(Level::from_raw(u8::MAX), None);
    }

    #[test]
    fn set_level_roundtrip() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }
}
