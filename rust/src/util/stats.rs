//! Descriptive statistics used by the bench harness and the coordinator's
//! latency metrics.

/// Summary of a sample: mean, standard deviation, percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary over `xs` (empty input yields all-zero summary).
    ///
    /// NaN samples never panic: sorting uses `f64::total_cmp`, which
    /// places positive NaN after `+∞` (and negative NaN before `-∞`), so a
    /// stray NaN latency sample (e.g. a degraded-chip `svc_inflation`
    /// edge case) lands in `max` — and propagates into `mean`/`std_dev` as
    /// NaN — instead of aborting the whole report.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std_dev: 0.0, min: 0.0, p50: 0.0, p90: 0.0, p95: 0.0, p99: 0.0, max: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Geometric mean (used for the cross-workload normalized summaries, like
/// the paper's "on average across all the models" claims).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let logsum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (logsum / xs.len() as f64).exp()
}

/// Online mean/variance accumulator (Welford) for streaming metrics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n > 1 { self.m2 / (self.n - 1) as f64 } else { 0.0 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        // rank 0.95 * 4 = 3.8 → 4 + 0.8 * (5 - 4)
        assert!((s.p95 - 4.8).abs() < 1e-12);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_survives_nan_samples() {
        // regression: partial_cmp(..).unwrap() panicked on the first NaN
        // latency sample; total_cmp sorts it after +∞ instead.
        let s = Summary::of(&[1.0, f64::NAN, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN must sort last, into max");
        assert_eq!(s.p50, 2.0, "median of [1, 2, NaN] by total order");
        assert!(s.mean.is_nan(), "NaN propagates through the mean");
        // finite-only input is untouched by the ordering change
        let t = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!((t.min, t.p50, t.max), (1.0, 2.0, 3.0));
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_hand_value() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std_dev() - s.std_dev).abs() < 1e-12);
    }
}
