//! ASCII table rendering for experiment / bench output.
//!
//! The benches regenerate the paper's tables and figure series as text; this
//! module is the shared pretty-printer.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Override per-column alignment (defaults to right-aligned).
    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from displayable items.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match aligns[i] {
                    Align::Left => s.push_str(&format!(" {}{} |", cell, " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cell)),
                }
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers, &widths, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with engineering-style precision (3 significant-ish
/// decimals, trimming noise) — used across experiment output.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.1}")
    } else if a >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_padding() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["alpha".into(), "1".into()]);
        t.row(&["b".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| alpha"));
        // all data lines equal width
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(0.2234), "0.223");
        assert_eq!(fnum(3.14159), "3.14");
        assert_eq!(fnum(42.42), "42.4");
        assert_eq!(fnum(12345.6), "12346");
    }
}
