//! Deterministic pseudo-random number generation.
//!
//! `rand` is unavailable offline; this module provides SplitMix64 (seeding)
//! and xoshiro256++ (bulk generation) — the same generators the reference
//! `rand_xoshiro` crate ships. All simulator stochasticity (workload
//! generation, sparsity sampling, property tests) flows through [`Rng`] so
//! every run is reproducible from a single `u64` seed.

/// SplitMix64 step; used to expand a seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// sufficient for workload jitter).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Derive an independent child generator (for parallel workers and
    /// per-layer / per-trial sub-streams).
    ///
    /// Rng hygiene: never `clone()` a generator you keep using — the clone
    /// replays the parent's exact stream, silently correlating everything
    /// drawn from both. Never seed siblings with sequential integers
    /// either; derive sub-seeds through `fork()` (or
    /// [`splitmix64`] for raw seeds), which advances the parent so every
    /// child stream is independent.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should occur");
    }

    #[test]
    fn chance_rate_roughly_correct() {
        let mut r = Rng::new(11);
        let hits = (0..100_000).filter(|_| r.chance(0.25)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.25).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn clone_replays_the_parent_stream_fork_does_not() {
        // the hygiene hazard fork() exists to prevent: a clone is a
        // correlated (identical) stream, a fork is an independent one
        let mut parent = Rng::new(99);
        let mut cloned = parent.clone();
        assert_eq!(
            (0..8).map(|_| parent.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| cloned.next_u64()).collect::<Vec<_>>(),
            "clone replays the parent stream"
        );
        let mut parent = Rng::new(99);
        let mut forked = parent.fork();
        assert_ne!(
            (0..8).map(|_| parent.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| forked.next_u64()).collect::<Vec<_>>(),
            "fork must not replay the parent stream"
        );
    }
}
