//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `hcim <subcommand> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse(raw: &[String]) -> crate::Result<Args> {
        let mut a = Args::default();
        let mut it = raw.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                anyhow::ensure!(!name.is_empty(), "empty flag name");
                // `--key=value` or `--key value` or boolean switch
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.flags.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    a.switches.push(name.to_string());
                }
            } else {
                a.positional.push(arg.clone());
            }
        }
        Ok(a)
    }

    pub fn from_env() -> crate::Result<Args> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// `--name` as usize, `default` when absent. A present-but-malformed
    /// value is an **error**, never a silent fallback — `--workers x`
    /// must not quietly become `--workers 2`.
    pub fn usize_or(&self, name: &str, default: usize) -> crate::Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{name}: invalid value `{v}` (expected an unsigned integer)")
            }),
        }
    }

    /// `--name` as f64, `default` when absent; malformed values error.
    pub fn f64_or(&self, name: &str, default: f64) -> crate::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{name}: invalid value `{v}` (expected a number)")
            }),
        }
    }

    /// Seed-style flag: decimal or `0x`-prefixed hex, `default` when
    /// absent; malformed values error.
    pub fn u64_or(&self, name: &str, default: u64) -> crate::Result<u64> {
        let Some(v) = self.flag(name) else { return Ok(default) };
        let parsed = if let Some(hex) = v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
            u64::from_str_radix(hex, 16).ok()
        } else {
            v.parse().ok()
        };
        parsed.ok_or_else(|| {
            anyhow::anyhow!("--{name}: invalid value `{v}` (expected decimal or 0x-hex u64)")
        })
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
hcim — ADC-Less Hybrid Analog-Digital CiM accelerator (paper reproduction)

USAGE:
  hcim <command> [options]

TELEMETRY (serve | fleet | dse | robustness | timeline):
  --trace FILE    write a Chrome trace_event JSON (open in Perfetto or
                  chrome://tracing). `timeline` exports the virtual-clock
                  span journal (crossbar groups, DCiM occupancy, NoC
                  activity); the other commands export wall-clock spans.
                  Also embeds the instrument-registry snapshot. Never
                  changes the deterministic report JSONs.
  --progress      stream `{done,total,rate,eta_s}` progress lines for
                  fan-out work (DSE points, Monte Carlo trials, serve
                  batches) to stderr at info level; without it the same
                  lines still appear under HCIM_LOG=debug

POWER (timeline | serve | fleet):
  --power         bin every event's energy into fixed virtual-time
                  windows and add a `power` section to the report:
                  per-channel windowed mW series with peak/avg/p99.
                  `timeline` channels are resource classes (xbar, dcim,
                  noc, adc, peripheral) with per-layer attribution and
                  analytic-vs-measured sparsity; `serve` channels are
                  tenant models; `fleet` channels are chips. Purely
                  virtual-clock: the section is byte-identical across
                  runs and pool sizes. `dse` always prices a power
                  trace per point (the peak_power_mw column).
  --power-window-ns N   binning window in virtual ns (default 0 =
                  auto: smallest 1/2/5*10^k giving <=128 windows)

COMMANDS:
  simulate    run the cycle-accurate simulator on a model
                --model resnet20|resnet32|resnet44|wrn20|vgg9|vgg11|resnet18
                --config A|B   --arch hcim|binary|adc7|adc6|adc4|quarry1|quarry4|bitsplit
                --node 65nm|32nm   [--sparsity artifacts/sparsity.json]
  serve       batched inference over the AOT artifacts
                --artifacts DIR  --requests N  --max-batch N  --workers N
                --seed S         master seed for the synthetic request stream
              multi-tenant (chip-sharded) mode:
                --models resnet20,vgg9[,...]   comma-separated zoo tenants;
                                 `model:weight` biases the tile split and the
                                 round-robin dispatch (default weight 1)
                --tiles N        chip crossbar-tile budget partitioned across
                                 tenants (each floored at its largest layer)
                --requests N     open-loop arrivals per tenant (default 64)
                --gap-us F       mean exponential inter-arrival gap (default 500)
                --arrivals exp|bursty   arrival process: open-loop exponential
                                 or seeded two-state bursty on/off (default exp)
                --queue-cap N    per-tenant admission bound (default 32)
                --format table|json   json prints ONLY the seed-deterministic
                                 metrics (byte-identical across runs/pool sizes)
                --out FILE       also write the full report (incl. wall-clock)
                --timeline       price each tenant's service time with the
                                 discrete-event timeline on its shard (weight-
                                 reprogramming rounds replace the analytical
                                 demand/shard inflation) and report per-
                                 component utilization in the metrics JSON
                --power          per-tenant virtual-time power section
                                 (see POWER above)
              admission, virtual latencies, and energy attribution are
              deterministic from --seed; real execution on the shared pool
              additionally runs when --artifacts has a manifest
  fleet       multi-chip fault-injected fleet serving on the virtual clock
                --models resnet20,vgg9[,...]   replicated zoo tenants
                                 (`model:weight` as in serve; default both)
                --chips N        chips in the fleet (default 4)
                --replicas N     replicas per tenant, placed on chips
                                 (tenant+r) mod chips (default 2, clamped)
                --tiles N        per-chip crossbar-tile budget (default 0 =
                                 midway between tenant floor and full demand)
                --faults SPEC    comma-joined fault schedule (default none):
                                 fail@C:T    chip C fail-stops at T µs
                                 stall@C:T+D chip C freezes for D µs at T
                                 degrade@C:TxF service/flip-rate inflation
                                 from the nonideal models at severity F
                --arrivals exp|bursty   arrival process (default exp)
                --requests N     arrivals per tenant (default 64)
                --gap-us F       mean inter-arrival gap (default 500)
                --queue-cap N    per-lane admission bound (default 16)
                --retries N      retry budget per request (default 3)
                --backoff-us N   base retry backoff; attempt k waits
                                 backoff << k (default 500)
                --stall-us N     health-monitor detection horizon in virtual
                                 µs (default 3000)
                --seed S         master seed (arrivals + degradation)
                --format table|json   json prints the deterministic fleet
                                 report, byte-identical across runs
                --out FILE       also write the report JSON
                --journal DIR    record the finished report as a durable
                                 trial; a re-run with the same configuration
                                 replays it instead of re-simulating
                --power          per-chip virtual-time power section (see
                                 POWER above; changes the journal key)
              a fail-stop never aborts the run: the health monitor drains
              the chip, survivors re-plan with the displaced tenants'
              weights doubled, and displaced requests retry with
              exponential backoff or count as dropped_after_retry
  tables      print every paper table/figure reproduction
                --artifacts DIR
                --journal DIR    journal the timeline-utilization sweep's
                                 cells and resume completed ones
  dse         parallel design-space exploration with Pareto extraction
                --workload resnet20[,vgg9,...]   comma-separated zoo models
                --out DIR        report/cache directory (default dse_out)
                --workers N      worker threads (default: all cores)
                --no-cache       ignore and do not write the result cache
                --journal DIR    durable flight recorder: fsync each finished
                                 point as a JSONL trial record; a killed sweep
                                 resumes from DIR with a byte-identical report
                                 (supersedes the whole-file cache.json)
                --sparsity FILE  measured sparsity table (artifacts/sparsity.json)
                --robustness     also Monte Carlo each point's PSQ flip rate
                                 and extend the Pareto frontier to 4 objectives
                --trials N       robustness trials per point (default 8)
                --seed S         robustness master seed (default 42)
              running a sweep:
                `hcim dse --workload resnet20` prices 24 design points
                (crossbar 64/128 x node 32/65nm x 6 peripheries) in
                parallel, then writes dse_out/sweep.{json,csv} with the
                (energy, latency, area) Pareto frontier marked
  robustness  Monte Carlo analog non-ideality analysis of the PSQ path
                --model NAME     zoo model (default resnet20)
                --config A|B|imagenet   --node 65nm|32nm|22nm
                --trials N       independent trials (default 32)
                --seed S         master seed; trial seeds derive via SplitMix64
                --workers N      worker threads (0 = all cores; the report is
                                 byte-identical for any worker count)
                --sigma-g F      log-normal conductance sigma
                --stuck-on F --stuck-off F   stuck-at cell fault rates
                --ir-drop F      far-row bitline attenuation fraction
                --sigma-cmp F    comparator offset sigma (popcount LSBs)
                --ideal          zero every magnitude (regression guard:
                                 measured flip rate must be exactly 0)
                --format table|json|csv   stdout format (default table)
                --out DIR        also write robustness.{json,csv}
                --journal DIR    journal every finished trial; a killed run
                                 resumes from DIR (same final report bytes)
  timeline    deterministic discrete-event chip timeline: per-layer tile
              tasks pipelined onto crossbar tiles, the DCiM array, and the
              mesh NoC (makespan, utilization, link contention)
                --model NAME     zoo model (default resnet20)
                --config A|B|imagenet   --node 65nm|32nm|22nm
                --arch hcim|binary|adc7|adc6|adc4|quarry1|quarry4|bitsplit
                --batch N        images scheduled concurrently (default 1)
                --chunks N       pipelining chunks per layer (default 8)
                --tiles N        optional crossbar-tile budget: layers time-
                                 multiplex in weight-reprogramming rounds
                --sparsity FILE  measured sparsity table
                --format table|json|csv   stdout format (default table);
                                 json/csv are byte-identical across runs
                --out DIR        also write timeline.{json,csv} (plus
                                 timeline.power.csv with --power)
                --vcd FILE       Gantt-style VCD trace (one signal per
                                 resource; open in GTKWave). With --power
                                 it also carries power.{class} uW signals
                --trace FILE     Chrome trace_event JSON of the same busy
                                 intervals on the virtual clock (Perfetto).
                                 With --power it gains per-class counter
                                 tracks (mW vs virtual time)
                --power          see POWER above (adds the report section
                                 and the exports; --power-window-ns N)
  journal     inspect a --journal directory (schema hcim-journal-v1)
                summarize [DIR]  per-sweep rollup: trials/ok/failed/keys,
                                 last heartbeat progress, stall detection
                  --stall-s F    heartbeat-silence threshold before an
                                 incomplete sweep reads STALLED (default 30)
                  --format table|json
                tail [DIR]       print the last raw records
                  --lines N      how many (default 20)
                  --follow       keep polling for new complete lines
                diff DIR_A DIR_B compare latest records per trial key;
                                 exits non-zero unless the journals agree
                the directory may also be passed as --journal DIR
  info        show a model's crossbar mapping (Eq. 2 bookkeeping)
                --model NAME --config A|B
  help        this message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse(&["simulate", "--model", "resnet20", "--quiet", "--config=B", "extra"]);
        assert_eq!(a.subcommand, "simulate");
        assert_eq!(a.flag("model"), Some("resnet20"));
        assert_eq!(a.flag("config"), Some("B"));
        assert!(a.has("quiet"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_accessors_with_defaults() {
        let a = parse(&["serve", "--requests", "64", "--rate", "1.5"]);
        assert_eq!(a.usize_or("requests", 1).unwrap(), 64);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!((a.f64_or("rate", 0.0).unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(a.f64_or("absent", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn seed_flag_accepts_decimal_and_hex() {
        let a = parse(&["robustness", "--seed", "12345"]);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 12345);
        let b = parse(&["robustness", "--seed", "0xDEADBEEF"]);
        assert_eq!(b.u64_or("seed", 0).unwrap(), 0xDEADBEEF);
        let c = parse(&["robustness"]);
        assert_eq!(c.u64_or("seed", 42).unwrap(), 42);
    }

    #[test]
    fn malformed_numeric_flags_are_errors_not_defaults() {
        // the regression: `--seed not-a-number` used to silently fall
        // back to the default, hiding the typo from the user
        let a = parse(&["robustness", "--seed", "not-a-number"]);
        let err = a.u64_or("seed", 42).unwrap_err().to_string();
        assert!(err.contains("--seed") && err.contains("not-a-number"), "{err}");

        let b = parse(&["serve", "--requests", "12x", "--gap-us", "fast", "--tiles", "-3"]);
        assert!(b.usize_or("requests", 64).is_err());
        assert!(b.f64_or("gap-us", 500.0).is_err());
        assert!(b.usize_or("tiles", 0).is_err(), "negative values must not parse as usize");

        let c = parse(&["robustness", "--seed", "0xZZ"]);
        assert!(c.u64_or("seed", 42).is_err(), "bad hex digits must error");
        let d = parse(&["serve", "--rate", "1.5.2"]);
        assert!(d.f64_or("rate", 0.0).is_err());
    }

    #[test]
    fn no_subcommand_ok() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, "");
        assert!(a.has("help"));
    }
}
