//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored path
//! dependency provides exactly the surface the `hcim` crate uses:
//!
//! * [`Error`] — a message plus an optional boxed source error,
//! * [`Result`] — `Result<T, Error>` with a defaulted error type,
//! * `anyhow!`, `bail!`, `ensure!` — format-string constructors.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` itself: that is what makes the blanket
//! `From<E: std::error::Error>` impl (and therefore `?` conversion from any
//! concrete error type) coherent.

use std::fmt;

/// A catch-all error: human-readable message plus optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a plain message (what `anyhow!` expands to).
    pub fn msg<M: Into<String>>(m: M) -> Error {
        Error { msg: m.into(), source: None }
    }

    /// The message this error was created with.
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// The wrapped source error, if this came from a typed error via `?`.
    pub fn source_ref(&self) -> Option<&(dyn std::error::Error + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().map(|s| s as &dyn std::error::Error);
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

/// Any concrete error converts via `?` (mirrors anyhow's blanket impl).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_debug() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        assert_eq!(format!("{e:?}"), "boom");
    }

    #[test]
    fn from_typed_error_keeps_source() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
        assert!(e.source_ref().is_some());
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(200).unwrap_err().to_string().contains("too big"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn ensure_without_message_names_condition() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("1 + 1 == 3"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
