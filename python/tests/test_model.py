"""L2 model tests: shapes, modes, calibration, and a training smoke test."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as data_mod
from compile.model import (
    ModelCfg,
    QuantSpec,
    apply_model,
    calibrate_model,
    im2col,
    init_model,
    model_presets,
    model_structure,
    mvm_forward,
)


@pytest.fixture(scope="module")
def tiny_cfg():
    return model_presets()["tiny"]


@pytest.fixture(scope="module")
def batch(tiny_cfg):
    (x, y), _ = data_mod.train_test_split(16, 4, image=tiny_cfg.image)
    return jnp.asarray(x), jnp.asarray(y)


def test_im2col_shapes():
    x = jnp.zeros((2, 8, 8, 3))
    patches, (oh, ow) = im2col(x, 3, 1, 1)
    assert patches.shape == (2, 64, 27)
    assert (oh, ow) == (8, 8)
    patches, (oh, ow) = im2col(x, 3, 2, 1)
    assert (oh, ow) == (4, 4)


@pytest.mark.parametrize("mode", ["fp", "adc7", "adc4", "binary", "ternary", "2bit"])
def test_forward_shapes_all_modes(tiny_cfg, batch, mode):
    cfg = dataclasses.replace(
        tiny_cfg, quant=dataclasses.replace(tiny_cfg.quant, mode=mode)
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    x, _ = batch
    logits, new_params = apply_model(params, x, cfg, train=True)
    assert logits.shape == (x.shape[0], cfg.classes)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_structure_matches_params(tiny_cfg):
    plan, feat = model_structure(tiny_cfg)
    params = init_model(jax.random.PRNGKey(0), tiny_cfg)
    assert len(plan) == len(params["layers"])
    assert params["fc"]["w"].shape == (feat, tiny_cfg.classes)


def test_eq2_scale_factor_shapes(tiny_cfg):
    """#SF per layer = groups × x_bits × (cols·w_bits / share) — Eq. 2."""
    spec = tiny_cfg.quant
    params = init_model(jax.random.PRNGKey(0), tiny_cfg)
    mvm = params["layers"][0]["mvm"]
    r, c = mvm["w"].shape
    groups = max(1, -(-r // spec.xbar_rows))
    assert mvm["scales"].shape == (groups, spec.x_bits, c * spec.w_bits)


def test_sf_share_reduces_scale_count(tiny_cfg):
    spec = dataclasses.replace(tiny_cfg.quant, sf_share=4)
    cfg = dataclasses.replace(tiny_cfg, quant=spec)
    params = init_model(jax.random.PRNGKey(0), cfg)
    mvm = params["layers"][0]["mvm"]
    c = mvm["w"].shape[1]
    assert mvm["scales"].shape[2] == (c * spec.w_bits) // 4
    # forward still works
    (x, _), _ = data_mod.train_test_split(4, 1, image=cfg.image)
    logits, _ = apply_model(params, jnp.asarray(x), cfg, train=False)
    assert logits.shape[1] == cfg.classes


def test_calibration_improves_psq_correlation(tiny_cfg, batch):
    cfg = dataclasses.replace(
        tiny_cfg, quant=dataclasses.replace(tiny_cfg.quant, mode="ternary")
    )
    params = init_model(jax.random.PRNGKey(1), cfg)
    x, _ = batch
    patches, _ = im2col(x, 3, 1, 1)
    b, np_, r = patches.shape
    x2d = patches.reshape(b * np_, r)

    def corr(p):
        mvm = p["layers"][0]["mvm"]
        psq = np.asarray(mvm_forward(mvm, x2d, cfg.quant, False)).ravel()
        fp = np.asarray(
            mvm_forward(mvm, x2d, dataclasses.replace(cfg.quant, mode="fp"), False)
        ).ravel()
        return np.corrcoef(psq, fp)[0, 1]

    calibrated = calibrate_model(params, x, cfg)
    assert corr(calibrated) > 0.3, "calibrated PSQ must track the ideal matmul"


def test_train_smoke_improves_over_random():
    from compile.train import train

    cfg = model_presets()["tiny"]
    cfg = dataclasses.replace(cfg, quant=dataclasses.replace(cfg.quant, mode="fp"))
    r = train(cfg, steps=150, batch=16, lr=1e-2, n_train=256, n_test=128,
              verbose=False)
    assert r.test_acc > 0.2, f"fp training should beat chance, got {r.test_acc}"


def test_transfer_params_reshapes_quant_state():
    from compile.train import transfer_params

    base = model_presets()["tiny"]
    fp_cfg = dataclasses.replace(base, quant=dataclasses.replace(base.quant, mode="fp"))
    src = init_model(jax.random.PRNGKey(0), fp_cfg)
    tern_cfg = dataclasses.replace(
        base, quant=dataclasses.replace(base.quant, mode="ternary", sf_share=4)
    )
    dst = transfer_params(src, tern_cfg)
    # weights copied, scales re-shaped for the new share factor
    np.testing.assert_array_equal(
        np.asarray(dst["fc"]["w"]), np.asarray(src["fc"]["w"])
    )
    c = dst["layers"][0]["mvm"]["w"].shape[1]
    assert dst["layers"][0]["mvm"]["scales"].shape[2] == c * 4 // 4


def test_dataset_determinism_and_balance():
    x1, y1 = data_mod.make_dataset(128, image=8, seed=3)
    x2, y2 = data_mod.make_dataset(128, image=8, seed=3)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    assert len(np.unique(y1)) == 10
