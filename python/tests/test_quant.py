"""Quantizer unit/property tests (L2 building blocks)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.psq.quant import (
    adc_quantize,
    lsq_codes,
    lsq_init_step,
    lsq_quantize,
    psq_binary,
    psq_ternary,
    round_ste,
)


@settings(deadline=None, max_examples=50)
@given(st.floats(-100, 100))
def test_round_ste_forward(x):
    assert float(round_ste(jnp.asarray(x))) == float(np.round(x))


def test_round_ste_gradient_is_identity():
    g = jax.grad(lambda x: round_ste(x) * 3.0)(1.234)
    assert abs(float(g) - 3.0) < 1e-6


@settings(deadline=None, max_examples=40)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    step=st.floats(0.01, 2.0),
    x=st.floats(-20, 20),
    signed=st.booleans(),
)
def test_lsq_quantize_error_bound(bits, step, x, signed):
    q = float(lsq_quantize(jnp.asarray(x), jnp.asarray(step), bits, signed=signed))
    qmin = -(2 ** (bits - 1)) if signed else 0
    qmax = (2 ** (bits - 1) - 1) if signed else (2**bits - 1)
    lo, hi = qmin * step, qmax * step
    tol = 1e-5 * max(1.0, abs(lo), abs(hi))  # f32 forward vs f64 oracle
    if lo + step / 2 <= x <= hi - step / 2:
        assert abs(q - x) <= step / 2 + tol
    assert lo - tol <= q <= hi + tol


def test_lsq_codes_integer_range():
    x = jnp.linspace(-5, 5, 101)
    codes = lsq_codes(x, 0.5, 4, signed=True)
    assert int(codes.min()) >= -8 and int(codes.max()) <= 7
    assert codes.dtype == jnp.int32


def test_lsq_step_gets_gradient():
    def f(step):
        return jnp.sum(lsq_quantize(jnp.asarray([0.3, -1.2, 2.0]), step, 4))

    g = float(jax.grad(f)(jnp.asarray(0.25)))
    assert g != 0.0


def test_psq_binary_values_and_grad():
    z = jnp.asarray([-3.0, -0.0, 0.0, 5.0])
    p = psq_binary(z)
    np.testing.assert_array_equal(np.asarray(p), [-1.0, 1.0, 1.0, 1.0])
    g = jax.grad(lambda v: jnp.sum(psq_binary(v) * 2.0))(z)
    assert np.all(np.asarray(g) == 2.0)  # straight-through


def test_psq_ternary_eq1():
    a = 2.0
    z = jnp.asarray([-5.0, -2.0, -1.9, 0.0, 1.9, 2.0, 5.0])
    p = np.asarray(psq_ternary(z, a))
    np.testing.assert_array_equal(p, [-1, -1, 0, 0, 0, 1, 1])


def test_psq_ternary_alpha_gradient_exists():
    g = jax.grad(lambda a: jnp.sum(psq_ternary(jnp.asarray([0.5, 3.0, -1.0]), a)))(2.0)
    assert np.isfinite(float(g))


@settings(deadline=None, max_examples=30)
@given(bits=st.sampled_from([2, 4, 7]), fs=st.floats(1.0, 100.0),
       x=st.floats(-150.0, 150.0))
def test_adc_quantize_bounds(bits, fs, x):
    q = float(adc_quantize(jnp.asarray(x), bits, fs))
    assert -fs - 1e-4 <= q <= fs + 1e-4
    if -fs <= x <= fs:
        step = 2 * fs / (2**bits - 1)
        assert abs(q - x) <= step / 2 + 1e-4


def test_adc_more_bits_less_error():
    xs = jnp.linspace(-10, 10, 201)
    errs = []
    for bits in (2, 4, 7):
        q = adc_quantize(xs, bits, 10.0)
        errs.append(float(jnp.abs(q - xs).max()))
    assert errs[0] > errs[1] > errs[2]


def test_lsq_init_step_positive():
    assert float(lsq_init_step(jnp.asarray([0.1, -0.5]), 4)) > 0
    assert float(lsq_init_step(jnp.zeros(4), 8)) > 0
