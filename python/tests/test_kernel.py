"""L1 kernel correctness: Pallas PSQ-MVM vs the pure-jnp oracle, swept over
shapes/precisions/modes with hypothesis."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.psq_mvm import psq_mvm_pallas, TILE_COLS, TILE_ROWS


def run_both(rng, b, r, c, w_bits, x_bits, theta, alpha, ternary):
    w = rng.integers(-(2 ** (w_bits - 1)), 2 ** (w_bits - 1), (r, c))
    x = rng.integers(0, 2**x_bits, (b, r))
    s = rng.integers(-7, 8, (x_bits, c * w_bits))
    planes = ref.weight_bitplanes(w, w_bits)
    phys = jnp.transpose(planes, (1, 2, 0)).reshape(r, c * w_bits)
    ps_ref, p = ref.psq_mvm_ref(
        x, w, s, theta=theta, alpha=alpha, w_bits=w_bits, x_bits=x_bits,
        ternary=ternary,
    )
    ps_kernel = psq_mvm_pallas(
        jnp.asarray(x), phys.astype(jnp.int32), jnp.asarray(s),
        x_bits=x_bits, theta=theta, alpha=alpha, ternary=ternary,
    )
    return np.asarray(ps_ref), np.asarray(ps_kernel), np.asarray(p)


@settings(deadline=None, max_examples=25)
@given(
    b=st.integers(1, 4),
    r=st.integers(1, 96),
    c=st.integers(1, 8),
    w_bits=st.sampled_from([2, 3, 4]),
    x_bits=st.sampled_from([1, 2, 4]),
    theta=st.floats(0.0, 30.0),
    alpha=st.floats(0.5, 8.0),
    ternary=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_oracle(b, r, c, w_bits, x_bits, theta, alpha, ternary, seed):
    rng = np.random.default_rng(seed)
    ps_ref, ps_kernel, _ = run_both(rng, b, r, c, w_bits, x_bits, theta, alpha, ternary)
    np.testing.assert_array_equal(ps_ref, ps_kernel)


def test_kernel_row_tiling_accumulates_like_hardware():
    """Rows beyond one crossbar tile split into separate kernel passes whose
    partial sums add — same digital accumulation the chip performs."""
    rng = np.random.default_rng(7)
    r = TILE_ROWS + 40  # forces 2 row tiles
    b, c, w_bits, x_bits = 2, 3, 4, 4
    w = rng.integers(-8, 8, (r, c))
    x = rng.integers(0, 16, (b, r))
    s = rng.integers(-7, 8, (x_bits, c * w_bits))
    planes = ref.weight_bitplanes(w, w_bits)
    phys = jnp.transpose(planes, (1, 2, 0)).reshape(r, c * w_bits)
    got = psq_mvm_pallas(jnp.asarray(x), phys.astype(jnp.int32), jnp.asarray(s),
                         x_bits=x_bits, theta=10.0, alpha=2.0)
    # reference: run each row tile independently and sum
    total = np.zeros((b, c * w_bits), np.int64)
    for lo in range(0, r, TILE_ROWS):
        hi = min(lo + TILE_ROWS, r)
        ps, _ = ref.psq_mvm_ref(x[:, lo:hi], w[lo:hi], s, theta=10.0, alpha=2.0,
                                w_bits=w_bits, x_bits=x_bits)
        total += np.asarray(ps)
    np.testing.assert_array_equal(total, np.asarray(got))


def test_kernel_column_tiling():
    """More physical columns than one tile → grid walks column tiles."""
    rng = np.random.default_rng(9)
    c = (TILE_COLS // 4) + 10  # phys cols = c*4 > 128
    b, r, w_bits, x_bits = 2, 32, 4, 2
    ps_ref, ps_kernel, _ = run_both(rng, b, r, c, w_bits, x_bits, 8.0, 1.5, True)
    np.testing.assert_array_equal(ps_ref, ps_kernel)


def test_per_stream_theta():
    rng = np.random.default_rng(11)
    b, r, c, w_bits, x_bits = 2, 24, 4, 4, 4
    w = rng.integers(-8, 8, (r, c))
    x = rng.integers(0, 16, (b, r))
    s = rng.integers(-7, 8, (x_bits, c * w_bits))
    planes = ref.weight_bitplanes(w, w_bits)
    phys = jnp.transpose(planes, (1, 2, 0)).reshape(r, c * w_bits)
    thetas = (2.0, 4.0, 6.0, 8.0)
    ps_ref, _ = ref.psq_mvm_ref(x, w, s, theta=thetas, alpha=1.0,
                                w_bits=w_bits, x_bits=x_bits)
    got = psq_mvm_pallas(jnp.asarray(x), phys.astype(jnp.int32), jnp.asarray(s),
                         x_bits=x_bits, theta=thetas, alpha=1.0)
    np.testing.assert_array_equal(np.asarray(ps_ref), np.asarray(got))


def test_binary_mode_has_no_zero_codes():
    rng = np.random.default_rng(3)
    _, _, p = run_both(rng, 2, 48, 4, 4, 4, theta=6.0, alpha=0.0, ternary=False)
    assert not (p == 0).any()


def test_ternary_dead_zone_creates_sparsity():
    rng = np.random.default_rng(5)
    _, _, p = run_both(rng, 4, 64, 6, 4, 4, theta=8.0, alpha=6.0, ternary=True)
    assert (p == 0).mean() > 0.1


def test_combine_slices_reconstructs_dense_mvm():
    """With exact scale factors s = 2^j·sw_i and no comparator loss
    (alpha=0, binary replaced by exact raw), the pipeline degenerates —
    check combine_slices folds physical columns correctly on a hand case."""
    ps = jnp.asarray([[1, 2, 3, 4, 10, 20, 30, 40]])  # 2 logical cols × 4 bits
    out = ref.combine_slices(ps, 4)
    np.testing.assert_array_equal(np.asarray(out), [[10, 100]])


def test_oracle_ps_bits_wraps():
    rng = np.random.default_rng(13)
    w = rng.integers(-8, 8, (16, 2))
    x = rng.integers(0, 16, (1, 16))
    s = np.full((4, 8), 127)  # force overflow
    ps, _ = ref.psq_mvm_ref(x, w, s, theta=0.0, alpha=0.0, w_bits=4, x_bits=4,
                            ternary=False, ps_bits=8)
    assert np.asarray(ps).min() >= -128 and np.asarray(ps).max() <= 127
