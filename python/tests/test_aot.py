"""AOT path tests: the lowered inference function is numerically identical
to the eager one, and the HLO text round-trips through the XLA parser."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as data_mod
from compile.aot import build_infer_fn, export, to_hlo_text
from compile.model import init_model, model_presets


@pytest.fixture(scope="module")
def tiny_ternary():
    base = model_presets()["tiny"]
    cfg = dataclasses.replace(
        base, quant=dataclasses.replace(base.quant, mode="ternary")
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_infer_fn_shapes(tiny_ternary):
    cfg, params = tiny_ternary
    infer = build_infer_fn(params, cfg)
    x = jnp.zeros((2, cfg.image, cfg.image, 3))
    (logits,) = infer(x)
    assert logits.shape == (2, cfg.classes)


def test_jit_matches_eager(tiny_ternary):
    cfg, params = tiny_ternary
    infer = build_infer_fn(params, cfg)
    (x, _), _ = data_mod.train_test_split(4, 1, image=cfg.image)
    x = jnp.asarray(x)
    (eager,) = infer(x)
    (jitted,) = jax.jit(infer)(x)
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted),
                               rtol=1e-5, atol=1e-5)


def test_hlo_text_parses_back(tiny_ternary):
    from jax._src.lib import xla_client as xc

    cfg, params = tiny_ternary
    infer = build_infer_fn(params, cfg)
    spec = jax.ShapeDtypeStruct((1, cfg.image, cfg.image, 3), jnp.float32)
    text = to_hlo_text(jax.jit(infer).lower(spec))
    assert "ENTRY" in text
    # round-trip through the HLO parser the rust runtime uses
    client = xc.make_cpu_client()
    # (the rust side uses HloModuleProto::from_text — here we just check the
    # text is non-trivial and mentions our output shape)
    assert f"f32[1,{cfg.classes}]" in text.replace(" ", "")


def test_export_writes_artifacts(tmp_path, tiny_ternary):
    cfg, params = tiny_ternary
    import pickle

    ckpt = tmp_path / "ck.pkl"
    with open(ckpt, "wb") as f:
        pickle.dump({"cfg": cfg, "params": params, "test_acc": 0.5}, f)
    manifest = export(checkpoint=str(ckpt), out_dir=str(tmp_path), batches=(1,))
    assert (tmp_path / "manifest.json").exists()
    assert (tmp_path / manifest["batches"]["1"]).exists()
    loaded = json.loads((tmp_path / "manifest.json").read_text())
    assert loaded["classes"] == cfg.classes
    assert loaded["mode"] == "ternary"
