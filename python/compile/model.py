"""Layer-2 JAX models with PSQ quantization-aware training.

Pure-JAX (no flax/optax in this offline image): parameters are nested
dicts, layers are functions. Every MVM layer (conv / linear) runs the
*crossbar-faithful* PSQ datapath:

  im2col → LSQ-quantize activations (unsigned, x_bits) and weights
  (signed, w_bits) → split input rows into crossbar groups (xbar_rows) →
  per group and per input bit-plane: popcount column sums over the weight
  bit-planes → comparator (binary/ternary, Eq. 1, trainable θ and α) →
  multiply by trainable quantized scale factors (sf_bits; the 2^j shift is
  merged in, §4.2) → accumulate → combine the w_bits slice columns.

This is the same arithmetic as `kernels/psq_mvm.py` (which the AOT path
lowers) and the rust gate-level DCiM model — the three are tested against
each other.

Quantization modes (``QuantSpec.mode``):
  * ``fp``      — float baseline (no PSQ),
  * ``adc{n}``  — n-bit ADC on group partial sums (Table 2 baselines),
  * ``binary``  — 1-bit PSQ with scale factors,
  * ``ternary`` — 1.5-bit PSQ with scale factors,
  * ``2bit``    — 2-bit partial sums *without* per-column scale factors
                  (the Fig. 2(b) strawman).

``sf_share`` > 1 shares one scale factor across that many columns
(the Fig. 2(d) accuracy-vs-#SF sweep).
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .psq.quant import (
    adc_quantize,
    lsq_init_step,
    lsq_quantize,
    psq_binary,
    psq_ternary,
    round_ste,
)


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantSpec:
    """Precision configuration (paper §5.1: CIFAR preset)."""

    mode: str = "ternary"      # fp | adc{n} | binary | ternary | 2bit
    w_bits: int = 4
    x_bits: int = 4
    sf_bits: int = 4
    ps_bits: int = 8
    xbar_rows: int = 128
    sf_share: int = 1          # Fig 2(d): share one SF across k columns

    @property
    def is_psq(self):
        return self.mode in ("binary", "ternary", "2bit")

    @property
    def adc_bits(self):
        return int(self.mode[3:]) if self.mode.startswith("adc") else None


@dataclasses.dataclass
class ModelCfg:
    """A slim CIFAR-style CNN (scaled-down ResNet/VGG — DESIGN.md
    substitution #3)."""

    name: str = "resnet20-slim"
    arch: str = "resnet"       # resnet | vgg
    widths: tuple = (8, 16, 32)
    blocks: int = 1            # residual blocks per stage
    image: int = 16            # input resolution
    classes: int = 10
    quant: QuantSpec = dataclasses.field(default_factory=QuantSpec)


# ---------------------------------------------------------------------------
# im2col + the PSQ MVM datapath
# ---------------------------------------------------------------------------


def im2col(x, k, stride, pad):
    """[B,H,W,C] → patches [B, OH*OW, k*k*C]."""
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(
                x[:, di : di + oh * stride : stride, dj : dj + ow * stride : stride, :]
            )
    patches = jnp.stack(cols, axis=3)  # [B, OH, OW, k*k, C]
    return patches.reshape(b, oh * ow, k * k * c), (oh, ow)


def psq_matmul(xq, wq, p, spec: QuantSpec, train: bool):
    """The crossbar-faithful MVM on *quantized codes*.

    xq: [N, R] unsigned codes in [0, 2^x_bits); values are STE-carrying
        floats during training.
    wq: [R, C] signed codes.
    p:  layer params dict (scale factors, theta, alpha, out_step).
    Returns float outputs [N, C] (scaled by out_step).

    Gradient routing (the PSQ-QAT recipe of [25]/§4.1):
    * the *forward* value is the bit-exact PSQ datapath (popcounts →
      comparator → Σ p·s_q),
    * weights/activations receive gradients through a layer-level STE
      residual against the ideal integer matmul (bit extraction itself
      has no useful gradient),
    * scale factors get exact gradients (`∂out/∂s = p`), θ and α get the
      comparator's soft gradients.
    """
    n, r = xq.shape
    c = wq.shape[1]
    g = spec.xbar_rows
    groups = max(1, math.ceil(r / g))

    if spec.mode == "fp":
        return xq @ wq * p["out_step"]

    # the differentiable "ideal" code-domain matmul (also the value PSQ
    # training regresses onto)
    ideal = xq @ wq

    if spec.adc_bits is not None:
        # N-bit ADC on each crossbar group's partial sum (PSQ-less baseline)
        out = jnp.zeros((n, c))
        for gi in range(groups):
            sl = slice(gi * g, min((gi + 1) * g, r))
            z = xq[:, sl] @ wq[sl]
            fs = jax.lax.stop_gradient(jnp.abs(z).max()) + 1e-6
            out = out + adc_quantize(z, spec.adc_bits, fs)
        return out * p["out_step"]

    # ---- full bit-plane PSQ path (binary / ternary / 2bit) ----
    # integer views for exact bit extraction (no gradient)
    xc = jax.lax.stop_gradient(jnp.round(xq)).astype(jnp.int32)
    wc = jax.lax.stop_gradient(jnp.round(wq)).astype(jnp.int32)
    theta = p["theta"]
    alpha = p["alpha"]
    out = jnp.zeros((n, c * spec.w_bits))
    for gi in range(groups):
        sl = slice(gi * g, min((gi + 1) * g, r))
        xg = xc[:, sl]
        wg = wc[sl]
        # two's complement weight bit-planes → physical column layout
        wpat = wg & ((1 << spec.w_bits) - 1)
        planes = [(wpat >> i) & 1 for i in range(spec.w_bits)]
        phys = jnp.stack(planes, axis=-1).reshape(wg.shape[0], c * spec.w_bits)
        phys_f = phys.astype(jnp.float32)
        for j in range(spec.x_bits):
            xbit = ((xg >> j) & 1).astype(jnp.float32)
            raw = xbit @ phys_f  # [N, c*w_bits] popcount partial sums
            centered = raw - theta[gi, j]
            if spec.mode == "binary":
                q = psq_binary(centered)
                s = p["scales"][gi, j]
            elif spec.mode == "ternary":
                q = psq_ternary(centered, alpha[gi])
                s = p["scales"][gi, j]
            else:  # 2bit: 2-bit symmetric partial sums, NO trainable SFs —
                # the shift-add applies the fixed 2^j · slice-weight pattern
                fs = jax.lax.stop_gradient(jnp.abs(centered).max()) + 1e-6
                q = adc_quantize(centered, 2, fs)
                sw = jnp.asarray(
                    [
                        -(2 ** (spec.w_bits - 1)) if i == spec.w_bits - 1 else 2**i
                        for i in range(spec.w_bits)
                    ],
                    jnp.float32,
                )
                out = out + q * (jnp.tile(sw, c) * 2.0**j)[None, :]
                continue
            if spec.sf_share > 1:
                s = jnp.repeat(s, spec.sf_share)[: c * spec.w_bits]
            # quantize the scale factors themselves (§4.1)
            s_q = lsq_quantize(s, jnp.exp(p["sf_step_log"]), spec.sf_bits, signed=True)
            out = out + q * s_q[None, :]
    # combine the w_bits physical columns of each logical output, plus the
    # θ-offset reconstruction bias (Σ_j 2^j·sw_i·θ_gj, learned)
    out = out.reshape(n, c, spec.w_bits).sum(axis=2) + p["bias"][None, :]
    # layer-level STE residual: forward unchanged, weight/activation
    # gradients flow as if the layer were the ideal integer matmul
    out = out + ideal - jax.lax.stop_gradient(ideal)
    return out * p["out_step"]


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def _init_scales(spec: QuantSpec, c, groups, rows_per_group):
    """Structured scale-factor init: s[g, j, col] = κ_g · 2^j · sw(col%w_bits)
    with sw the signed two's-complement slice weight."""
    n_sf = max(-(-(c * spec.w_bits) // max(spec.sf_share, 1)), 1)  # ceil
    sw = np.array(
        [
            -(2 ** (spec.w_bits - 1)) if i == spec.w_bits - 1 else 2**i
            for i in range(spec.w_bits)
        ],
        dtype=np.float32,
    )
    cols = np.tile(sw, c)[: n_sf * max(spec.sf_share, 1)][:: max(spec.sf_share, 1)]
    cols = cols[:n_sf]
    out = np.zeros((groups, spec.x_bits, n_sf), np.float32)
    for g, rg in enumerate(rows_per_group):
        kappa = max(np.sqrt(rg) * 0.4, 0.5)
        for j in range(spec.x_bits):
            out[g, j] = kappa * (2.0**j) * cols
    return jnp.asarray(out)


def init_mvm_params(rng, r, c, spec: QuantSpec, fan_in):
    """Parameters for one MVM layer (He-init weights + quantizer state)."""
    k1, _ = jax.random.split(rng)
    w = jax.random.normal(k1, (r, c)) * np.sqrt(2.0 / fan_in)
    groups = max(1, math.ceil(r / spec.xbar_rows))
    n_sf = max(-(-(c * spec.w_bits) // max(spec.sf_share, 1)), 1)  # ceil
    # comparator reference ≈ E[popcount] of the group's column
    # (rows · E[x_bit]·E[w_bit] ≈ rows/6 for LSQ-initialised nets); the
    # ternary dead-zone α starts at ~½σ of the popcount
    rows_per_group = [
        min(spec.xbar_rows, r - gi * spec.xbar_rows) for gi in range(groups)
    ]
    theta0 = jnp.asarray(
        [[rg / 6.0] * spec.x_bits for rg in rows_per_group]
    )  # [groups, x_bits]: the comparator reference can step per bit-stream
    alpha0 = jnp.asarray([max(np.sqrt(rg) * 0.4, 0.5) for rg in rows_per_group])
    params = {
        "w": w,
        # step sizes live in LOG domain: Adam's fixed-size steps would
        # otherwise drive these small positive scalars through zero
        "w_step_log": jnp.log(lsq_init_step(w, spec.w_bits, signed=True)),
        "x_step_log": jnp.asarray(np.log(0.125)),
        "out_step": jnp.asarray(1.0),
        "theta": theta0,
        "alpha": alpha0,
        # scale factors [groups, x_bits, n_sf]: physical column c·w_bits+i
        # carries the merged input shift 2^j AND the two's-complement
        # slice weight sw_i (−2^(w_bits−1) for the MSB slice) times the
        # expected comparator magnitude κ ≈ E|ps−θ| of the group — so the
        # PSQ forward approximates the ideal matmul from step 0 (§4.2:
        # "the shift operation is merged with the scale factor values").
        "scales": _init_scales(spec, c, groups, rows_per_group),
        # per-layer SF quantizer step sized to the largest |s| (§4.1)
        "sf_step_log": jnp.log(
            _init_scales(spec, c, groups, rows_per_group).max()
            / (2 ** (spec.sf_bits - 1) - 1)
            + 1e-9
        ),
        # θ-offset reconstruction bias (digital, folded into BN on silicon)
        "bias": jnp.zeros((c,)),
    }
    return params


def mvm_forward(params, x2d, spec: QuantSpec, train: bool):
    """Quantize activations + weights, then the PSQ matmul."""
    if spec.mode == "fp":
        return x2d @ params["w"] * params["out_step"]
    # unsigned activation codes (post-ReLU inputs)
    x_step = jnp.exp(params["x_step_log"])
    w_step = jnp.exp(params["w_step_log"])
    xq = lsq_quantize(jnp.maximum(x2d, 0.0), x_step, spec.x_bits, signed=False)
    x_codes = xq / x_step
    # signed weight codes
    wq = lsq_quantize(params["w"], w_step, spec.w_bits, signed=True)
    w_codes = wq / w_step
    out = psq_matmul(x_codes, w_codes, params, spec, train)
    # fold the quantizer steps back in (absorbed by BN in silicon)
    return out * x_step * w_step


def batchnorm_init(c):
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def batchnorm(params, x, train: bool, momentum=0.9):
    """BN over the channel (last) axis. Returns (y, updated_params)."""
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = x.mean(axis=axes)
        var = x.var(axis=axes) + 1e-5
        new = {
            **params,
            "mean": momentum * params["mean"] + (1 - momentum) * mean,
            "var": momentum * params["var"] + (1 - momentum) * var,
        }
    else:
        mean, var, new = params["mean"], params["var"] + 1e-5, params
    y = (x - mean) / jnp.sqrt(var) * params["gamma"] + params["beta"]
    return y, new


# ---------------------------------------------------------------------------
# model assembly
# ---------------------------------------------------------------------------


def conv_apply(params, x, spec, k, stride, pad, train):
    patches, (oh, ow) = im2col(x, k, stride, pad)
    b, np_, r = patches.shape
    out = mvm_forward(params, patches.reshape(b * np_, r), spec, train)
    return out.reshape(b, oh, ow, -1)


def model_structure(cfg: ModelCfg):
    """Static layer plan (kept OUT of the parameter pytree so jax never
    traces strings/ints). Each entry: dict of static attributes."""
    plan = []
    if cfg.arch == "resnet":
        w0, w1, w2 = cfg.widths
        plan.append({"kind": "conv", "cin": 3, "cout": w0, "k": 3, "stride": 1,
                     "pool": False})
        chans = [(w0, w0, 1)] * cfg.blocks + [(w0, w1, 2)] + [(w1, w1, 1)] * (
            cfg.blocks - 1
        ) + [(w1, w2, 2)] + [(w2, w2, 1)] * (cfg.blocks - 1)
        for cin, cout, stride in chans:
            plan.append({"kind": "block", "cin": cin, "cout": cout,
                         "stride": stride, "residual": stride == 1 and cin == cout})
        feat = w2
    else:  # vgg
        cin = 3
        for w in cfg.widths:
            plan.append({"kind": "conv", "cin": cin, "cout": w, "k": 3,
                         "stride": 1, "pool": True})
            cin = w
        feat = cfg.widths[-1]
    return plan, feat


def init_model(rng, cfg: ModelCfg):
    """Initialise the parameter pytree (arrays only) for `cfg`."""
    plan, feat = model_structure(cfg)
    keys = jax.random.split(rng, 2 * len(plan) + 2)
    ki = iter(keys)
    spec = cfg.quant

    def conv_p(cin, cout, k=3):
        return {
            "mvm": init_mvm_params(next(ki), k * k * cin, cout, spec, k * k * cin),
            "bn": batchnorm_init(cout),
        }

    layers = []
    for entry in plan:
        if entry["kind"] == "conv":
            layers.append(conv_p(entry["cin"], entry["cout"], entry["k"]))
        else:
            layers.append(
                {
                    "conv1": conv_p(entry["cin"], entry["cout"]),
                    "conv2": conv_p(entry["cout"], entry["cout"]),
                }
            )
    fc = init_mvm_params(next(ki), feat, cfg.classes, spec, feat)
    return {"layers": layers, "fc": fc}


def apply_model(params, x, cfg: ModelCfg, train: bool):
    """Forward pass. Returns (logits, updated_params-with-BN-state)."""
    spec = cfg.quant
    plan, _ = model_structure(cfg)
    new_layers = []
    for entry, lp in zip(plan, params["layers"]):
        if entry["kind"] == "conv":
            k = entry["k"]
            y = conv_apply(lp["mvm"], x, spec, k, entry["stride"], k // 2, train)
            y, bn = batchnorm(lp["bn"], y, train)
            x = jax.nn.relu(y)
            if entry["pool"]:
                x = x[:, ::2, ::2, :]  # stride-2 subsample (cheap pool stand-in)
            new_layers.append({**lp, "bn": bn})
        else:  # residual block
            skip = x
            y = conv_apply(lp["conv1"]["mvm"], x, spec, 3, entry["stride"], 1, train)
            y, bn1 = batchnorm(lp["conv1"]["bn"], y, train)
            y = jax.nn.relu(y)
            y = conv_apply(lp["conv2"]["mvm"], y, spec, 3, 1, 1, train)
            y, bn2 = batchnorm(lp["conv2"]["bn"], y, train)
            if entry["residual"]:
                y = y + skip
            x = jax.nn.relu(y)
            new_layers.append(
                {"conv1": {**lp["conv1"], "bn": bn1}, "conv2": {**lp["conv2"], "bn": bn2}}
            )
    x = x.mean(axis=(1, 2))  # global average pool
    logits = mvm_forward(params["fc"], x, spec, train)
    return logits, {**params, "layers": new_layers}


def model_presets():
    """The scaled-down stand-ins for the paper's workloads."""
    return {
        # widths kept modest so the PSQ fine-tune converges within the
        # offline step budget (DESIGN.md substitution #3)
        "resnet20-slim": ModelCfg(name="resnet20-slim", widths=(8, 16, 16)),
        "wide-resnet20-slim": ModelCfg(name="wide-resnet20-slim", widths=(16, 32, 32)),
        "vgg9-slim": ModelCfg(name="vgg9-slim", arch="vgg", widths=(16, 32, 64)),
        "tiny": ModelCfg(name="tiny", widths=(4, 8, 8), image=8),
    }


# ---------------------------------------------------------------------------
# PSQ calibration (run once before fine-tuning, on one batch)
# ---------------------------------------------------------------------------


def _calibrate_mvm(p, x2d, spec: QuantSpec):
    """Set θ (per group & bit-stream), α, and the scale-factor magnitudes
    from the actual popcount statistics of a calibration batch — the
    quantization-aware-training warm start of §4.1."""
    import numpy as onp

    x_step = jnp.exp(p["x_step_log"])
    w_step = jnp.exp(p["w_step_log"])
    xq = lsq_quantize(jnp.maximum(x2d, 0.0), x_step, spec.x_bits, signed=False) / x_step
    wq = lsq_quantize(p["w"], w_step, spec.w_bits, signed=True) / w_step
    xc = onp.asarray(jnp.round(xq), onp.int64)
    wc = onp.asarray(jnp.round(wq), onp.int64)
    r, c = wc.shape
    g = spec.xbar_rows
    groups = max(1, -(-r // g))

    theta = onp.zeros((groups, spec.x_bits), onp.float32)
    alpha = onp.zeros((groups,), onp.float32)
    scales = onp.asarray(p["scales"], onp.float32).copy()
    sw = onp.array(
        [-(2 ** (spec.w_bits - 1)) if i == spec.w_bits - 1 else 2**i
         for i in range(spec.w_bits)],
        dtype=onp.float32,
    )
    n_sf = scales.shape[2]
    share = max(spec.sf_share, 1)
    sw_cols = onp.tile(sw, c)[: n_sf * share][::share][:n_sf]

    shift_sw = onp.tile(sw, c)  # per physical column: ±2^i
    bias = onp.zeros((c,), onp.float32)
    # two passes: first θ and α from the raw statistics, then per-column
    # least-squares scales against the comparator codes.
    for gi in range(groups):
        sl = slice(gi * g, min((gi + 1) * g, r))
        wg = wc[sl] & ((1 << spec.w_bits) - 1)
        phys = onp.stack(
            [(wg >> i) & 1 for i in range(spec.w_bits)], axis=-1
        ).reshape(wg.shape[0], c * spec.w_bits).astype(onp.float32)
        raws = []
        sds = []
        for j in range(spec.x_bits):
            xbit = ((xc[:, sl] >> j) & 1).astype(onp.float32)
            raw = xbit @ phys
            raws.append(raw)
            theta[gi, j] = raw.mean()
            sds.append(raw.std() + 1e-6)
        alpha[gi] = 0.6 * float(onp.mean(sds))
        for j in range(spec.x_bits):
            centered = raws[j] - theta[gi, j]
            if spec.mode == "ternary":
                pc = onp.where(centered >= alpha[gi], 1.0,
                               onp.where(centered <= -alpha[gi], -1.0, 0.0))
            else:
                pc = onp.where(centered >= 0, 1.0, -1.0)
            num = (centered * pc).sum(0)
            den = (pc * pc).sum(0) + 1e-6
            s_reg = onp.maximum(num / den, 0.0)          # per physical column
            full = (2.0**j) * shift_sw * s_reg           # merge shift + slice sign
            scales[gi, j] = full[::share][:n_sf]
            # θ-offset contribution of this (group, j): same for every
            # logical column scaled by Σ_i sw_i
            bias += (2.0**j) * float(sw.sum()) * theta[gi, j]

    out = dict(p)
    out["theta"] = jnp.asarray(theta)
    out["alpha"] = jnp.asarray(alpha)
    out["scales"] = jnp.asarray(scales)
    out["bias"] = jnp.asarray(bias)
    out["sf_step_log"] = jnp.log(jnp.abs(out["scales"]).max() /
                                 (2 ** (spec.sf_bits - 1) - 1) + 1e-9)
    return out


def calibrate_model(params, x, cfg: ModelCfg):
    """Walk the network on batch `x`, calibrating every MVM layer's PSQ
    parameters to the statistics its actual inputs produce."""
    spec = cfg.quant
    if not spec.is_psq:
        return params
    plan, _ = model_structure(cfg)
    new_layers = []
    for entry, lp in zip(plan, params["layers"]):
        if entry["kind"] == "conv":
            k = entry["k"]
            patches, _ = im2col(x, k, entry["stride"], k // 2)
            b, np_, r = patches.shape
            mvm = _calibrate_mvm(lp["mvm"], patches.reshape(b * np_, r), spec)
            lp = {**lp, "mvm": mvm}
            y = conv_apply(mvm, x, spec, k, entry["stride"], k // 2, False)
            y, _ = batchnorm(lp["bn"], y, False)
            x = jax.nn.relu(y)
            if entry["pool"]:
                x = x[:, ::2, ::2, :]
        else:
            skip = x
            patches, _ = im2col(x, 3, entry["stride"], 1)
            b, np_, r = patches.shape
            mvm1 = _calibrate_mvm(lp["conv1"]["mvm"], patches.reshape(b * np_, r), spec)
            y = conv_apply(mvm1, x, spec, 3, entry["stride"], 1, False)
            y, _ = batchnorm(lp["conv1"]["bn"], y, False)
            y = jax.nn.relu(y)
            patches2, _ = im2col(y, 3, 1, 1)
            b2, np2, r2 = patches2.shape
            mvm2 = _calibrate_mvm(lp["conv2"]["mvm"], patches2.reshape(b2 * np2, r2), spec)
            y = conv_apply(mvm2, y, spec, 3, 1, 1, False)
            y, _ = batchnorm(lp["conv2"]["bn"], y, False)
            if entry["residual"]:
                y = y + skip
            x = jax.nn.relu(y)
            lp = {"conv1": {**lp["conv1"], "mvm": mvm1},
                  "conv2": {**lp["conv2"], "mvm": mvm2}}
        new_layers.append(lp)
    feat = x.mean(axis=(1, 2))
    fc = _calibrate_mvm(params["fc"], feat, spec)
    return {"layers": new_layers, "fc": fc}
