"""PSQ quantization-aware training driver (Layer 2).

Hand-rolled Adam (optax is unavailable offline). Used by:

* ``make train``      — trains the serving model and writes checkpoints
  for ``aot.py``,
* ``make accuracy``   — the Table 2 / Fig 2(b,d) sweeps → writes
  ``artifacts/accuracy.json``,
* ``make sparsity``   — measures comparator-code distributions →
  ``artifacts/sparsity.json`` (via export_sparsity.py).

Usage:
  python -m compile.train --preset tiny --mode ternary --steps 60
  python -m compile.train --accuracy-sweep --out ../artifacts/accuracy.json
"""

import argparse
import dataclasses
import json
import pathlib
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .model import (ModelCfg, QuantSpec, apply_model, calibrate_model, init_model,
                    model_presets)


# ---------------------------------------------------------------------------
# optimizer (Adam)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_f = 1.0 / (1 - b1**t)
    vhat_f = 1.0 / (1 - b2**t)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_f) / (jnp.sqrt(v_ * vhat_f) + eps),
        params,
        m,
        v,
    )
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------

_TRAINABLE_EXCLUDE = ("mean", "var")  # BN running stats are not trained


def _split_trainable(params):
    """Mask out BN running statistics from the gradient path."""

    def mask(path, _):
        return not any(p in _TRAINABLE_EXCLUDE for p in path)

    return mask


def loss_fn(params, x, y, cfg, train=True):
    logits, new_params = apply_model(params, x, cfg, train=train)
    onehot = jax.nn.one_hot(y, cfg.classes)
    ce = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
    return ce, (logits, new_params)


def accuracy(params, x, y, cfg, batch=256):
    correct = 0
    for i in range(0, len(x), batch):
        logits, _ = apply_model(params, x[i : i + batch], cfg, train=False)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + batch]))
    return correct / len(x)


def transfer_params(src_params, cfg: ModelCfg, seed=0):
    """Port weights/BN from a checkpoint into a freshly-initialised pytree
    for `cfg` (quantizer-structural arrays — scale factors, θ, α — are
    re-initialised to match the new quant spec's shapes)."""
    fresh = init_model(jax.random.PRNGKey(seed), cfg)

    def copy_mvm(dst, src):
        out = dict(dst)
        for k in ("w", "w_step_log", "x_step_log", "out_step"):
            if k in src:
                out[k] = src[k]
        return out

    layers = []
    for f, s in zip(fresh["layers"], src_params["layers"]):
        if "mvm" in f:
            layers.append({"mvm": copy_mvm(f["mvm"], s["mvm"]), "bn": s["bn"]})
        else:
            layers.append(
                {
                    "conv1": {"mvm": copy_mvm(f["conv1"]["mvm"], s["conv1"]["mvm"]),
                              "bn": s["conv1"]["bn"]},
                    "conv2": {"mvm": copy_mvm(f["conv2"]["mvm"], s["conv2"]["mvm"]),
                              "bn": s["conv2"]["bn"]},
                }
            )
    return {"layers": layers, "fc": copy_mvm(fresh["fc"], src_params["fc"])}


@dataclasses.dataclass
class TrainResult:
    cfg: ModelCfg
    params: dict
    train_acc: float
    test_acc: float
    losses: list
    seconds: float


def train(cfg: ModelCfg, steps=200, batch=32, lr=2e-3, n_train=2048, n_test=512,
          seed=0, log_every=25, verbose=True, init_params=None):
    (xtr, ytr), (xte, yte) = data_mod.train_test_split(
        n_train, n_test, image=cfg.image, classes=cfg.classes, seed=seed
    )
    params = init_params if init_params is not None else init_model(
        jax.random.PRNGKey(seed), cfg
    )
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, x, y):
        (loss, (_, new_params)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, x, y, cfg
        )
        # zero out grads of BN running stats; carry their updated values
        def scrub(path, g):
            return jnp.zeros_like(g) if any(k in str(path) for k in _TRAINABLE_EXCLUDE) else g

        grads = jax.tree_util.tree_map_with_path(
            lambda p, g: scrub(p, g), grads
        )
        new_train, new_opt = adam_update(params, grads, opt, lr)
        # splice the BN running stats from the forward pass
        def take_bn(path, trained, forward):
            return forward if any(k in str(path) for k in _TRAINABLE_EXCLUDE) else trained

        merged = jax.tree_util.tree_map_with_path(
            lambda p, a, b: take_bn(p, a, b), new_train, new_params
        )
        return merged, new_opt, loss

    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, n_train, batch)
        params, opt, loss = step_fn(params, opt, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
        losses.append(float(loss))
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"  step {step:4d}  loss {float(loss):.4f}", flush=True)
    seconds = time.time() - t0
    tr_acc = accuracy(params, jnp.asarray(xtr[:512]), ytr[:512], cfg)
    te_acc = accuracy(params, jnp.asarray(xte), yte, cfg)
    if verbose:
        print(f"  [{cfg.name}/{cfg.quant.mode}] train {tr_acc:.3f} test {te_acc:.3f} "
              f"({seconds:.1f}s)", flush=True)
    return TrainResult(cfg, params, tr_acc, te_acc, losses, seconds)


# ---------------------------------------------------------------------------
# sweeps (Table 2, Fig 2(b), Fig 2(d))
# ---------------------------------------------------------------------------


def accuracy_sweep(preset="resnet20-slim", steps=250, out=None, seed=0,
                   xbar_sizes=(128, 64), quick=False):
    """Reproduce the *shape* of Table 2 + Fig 2(b,d) on the synthetic set.

    Like the paper (and the PSQ work it builds on), quantized variants are
    *fine-tuned from a full-precision checkpoint* rather than trained from
    scratch — pretrain once per crossbar size, then fine-tune each
    precision from it.
    """
    base = model_presets()[preset]
    if quick:
        steps = 40
    ft_steps = max(int(steps * 1.5), 30)
    results = {"preset": preset, "steps": steps, "rows": []}

    modes = ["adc7", "adc6", "adc4", "2bit", "ternary", "binary"]
    pretrained = {}
    for xbar in xbar_sizes:
        fp_cfg = dataclasses.replace(
            base, quant=dataclasses.replace(base.quant, mode="fp", xbar_rows=xbar)
        )
        fp = train(fp_cfg, steps=steps, seed=seed, verbose=False)
        pretrained[xbar] = fp
        results["rows"].append(
            {"model": preset, "xbar": xbar, "adc_bits": "fp", "mode": "fp",
             "test_acc": fp.test_acc}
        )
        print(f"  xbar={xbar} fp pretrain: acc={fp.test_acc:.3f}", flush=True)
        for mode in modes:
            if mode == "adc7" and xbar == 64:
                continue  # the paper's Table 2 leaves 7-bit blank at 64×64
            cfg = dataclasses.replace(
                base,
                quant=dataclasses.replace(base.quant, mode=mode, xbar_rows=xbar),
            )
            p0 = transfer_params(fp.params, cfg, seed)
            if cfg.quant.is_psq:
                (cx, _), _ = data_mod.train_test_split(
                    64, 1, image=cfg.image, classes=cfg.classes, seed=seed)
                p0 = calibrate_model(p0, jnp.asarray(cx), cfg)
            r = train(cfg, steps=ft_steps, seed=seed, verbose=False,
                      init_params=p0, lr=5e-4)
            label = {"adc7": "7", "adc6": "6", "adc4": "4",
                     "2bit": "2 (no SF)", "ternary": "1.5", "binary": "1"}[mode]
            results["rows"].append(
                {"model": preset, "xbar": xbar, "adc_bits": label,
                 "mode": mode, "test_acc": r.test_acc}
            )
            print(f"  xbar={xbar} mode={mode}: acc={r.test_acc:.3f}", flush=True)
            if out:  # incremental write: a crash never loses finished rows
                pathlib.Path(out).parent.mkdir(parents=True, exist_ok=True)
                pathlib.Path(out).write_text(json.dumps(results, indent=1))

    # Fig 2(d): scale-factor sharing sweep (ternary, 128×128)
    for share in (1, 4, 16, 64):
        cfg = dataclasses.replace(
            base,
            quant=dataclasses.replace(base.quant, mode="ternary", sf_share=share),
        )
        p0 = transfer_params(pretrained[xbar_sizes[0]].params, cfg, seed)
        (cx, _), _ = data_mod.train_test_split(
            64, 1, image=cfg.image, classes=cfg.classes, seed=seed)
        p0 = calibrate_model(p0, jnp.asarray(cx), cfg)
        r = train(cfg, steps=ft_steps, seed=seed, verbose=False,
                  init_params=p0, lr=5e-4)
        results["rows"].append(
            {"model": preset, "xbar": 128, "adc_bits": "1.5",
             "mode": f"ternary/sf_share={share}", "sf_share": share,
             "test_acc": r.test_acc}
        )
        print(f"  sf_share={share}: acc={r.test_acc:.3f}", flush=True)
        if out:
            pathlib.Path(out).write_text(json.dumps(results, indent=1))

    if out:
        pathlib.Path(out).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(out).write_text(json.dumps(results, indent=1))
        print(f"wrote {out}")
    return results


def save_checkpoint(result: TrainResult, path):
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        pickle.dump({"cfg": result.cfg, "params": result.params,
                     "test_acc": result.test_acc}, f)
    print(f"wrote {path} (test acc {result.test_acc:.3f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--mode", default="ternary")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--accuracy-sweep", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.accuracy_sweep:
        accuracy_sweep(
            preset=args.preset if args.preset != "tiny" else "resnet20-slim",
            steps=args.steps,
            out=args.out,
            seed=args.seed,
            quick=args.quick,
        )
        return

    cfg = model_presets()[args.preset]
    cfg = dataclasses.replace(cfg, quant=dataclasses.replace(cfg.quant, mode=args.mode))
    r = train(cfg, steps=args.steps, batch=args.batch, seed=args.seed)
    if args.checkpoint:
        save_checkpoint(r, args.checkpoint)


if __name__ == "__main__":
    main()
