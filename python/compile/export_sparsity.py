"""Measure comparator-code (p) distributions of a trained ternary model and
export them for the rust simulator (Fig. 2(c) → `artifacts/sparsity.json`).

The rust `SparsityTable` consumes `{"<model>": {"layers": [f0, f1, ...]}}`
with one zero-fraction per MVM layer, in mapping order. The slim trained
model's per-layer fractions are exported under both its own name and the
corresponding full-size zoo names (the fractions are statistics of the
PSQ quantizer, which transfer across width — DESIGN.md substitution #5).

Usage:
  python -m compile.export_sparsity [--checkpoint ckpt.pkl]
                                    [--out ../artifacts/sparsity.json]
"""

import argparse
import dataclasses
import json
import pathlib
import pickle

import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .kernels.ref import psq_mvm_ref
from .model import ModelCfg, batchnorm, im2col, model_structure, model_presets
from .psq.quant import lsq_codes


def _layer_sparsity(p, x2d, spec):
    """Zero fraction of the comparator codes of one MVM layer."""
    x_step = float(np.exp(p["x_step_log"]))
    w_step = float(np.exp(p["w_step_log"]))
    xc = np.clip(np.round(np.maximum(np.asarray(x2d), 0.0) / x_step), 0,
                 2**spec.x_bits - 1).astype(np.int64)
    wc = np.asarray(lsq_codes(p["w"], w_step, spec.w_bits, signed=True))
    sf_step = float(np.exp(p["sf_step_log"]))
    r, c = wc.shape
    g = spec.xbar_rows
    groups = max(1, -(-r // g))
    zeros, total = 0, 0
    for gi in range(groups):
        sl = slice(gi * g, min((gi + 1) * g, r))
        s = np.asarray(p["scales"][gi])
        if spec.sf_share > 1:
            s = np.repeat(s, spec.sf_share, axis=1)[:, : c * spec.w_bits]
        s_codes = np.asarray(lsq_codes(jnp.asarray(s), sf_step, spec.sf_bits,
                                       signed=True))
        _, codes = psq_mvm_ref(
            xc[:, sl], wc[sl], jnp.asarray(s_codes),
            theta=tuple(float(t) for t in np.asarray(p["theta"][gi])),
            alpha=float(p["alpha"][gi]),
            w_bits=spec.w_bits, x_bits=spec.x_bits,
            ternary=spec.mode == "ternary",
        )
        codes = np.asarray(codes)
        zeros += int((codes == 0).sum())
        total += codes.size
    return zeros / max(total, 1)


def measure(params, cfg: ModelCfg, n=32, seed=0):
    """Per-MVM-layer zero fractions on a held-out batch."""
    spec = cfg.quant
    (x, _), _ = data_mod.train_test_split(n, 1, image=cfg.image,
                                          classes=cfg.classes, seed=seed + 77)
    x = jnp.asarray(x)
    plan, _ = model_structure(cfg)
    import jax

    fractions = []
    cur = x
    for entry, lp in zip(plan, params["layers"]):
        if entry["kind"] == "conv":
            k = entry["k"]
            patches, (oh, ow) = im2col(cur, k, entry["stride"], k // 2)
            b, np_, r = patches.shape
            fractions.append(_layer_sparsity(lp["mvm"], patches.reshape(b * np_, r), spec))
            # advance functionally (float path is fine for statistics)
            from .model import conv_apply
            y = conv_apply(lp["mvm"], cur, spec, k, entry["stride"], k // 2, False)
            y, _ = batchnorm(lp["bn"], y, False)
            cur = jax.nn.relu(y)
            if entry["pool"]:
                cur = cur[:, ::2, ::2, :]
        else:
            from .model import conv_apply
            patches, _ = im2col(cur, 3, entry["stride"], 1)
            b, np_, r = patches.shape
            fractions.append(
                _layer_sparsity(lp["conv1"]["mvm"], patches.reshape(b * np_, r), spec)
            )
            skip = cur
            y = conv_apply(lp["conv1"]["mvm"], cur, spec, 3, entry["stride"], 1, False)
            y, _ = batchnorm(lp["conv1"]["bn"], y, False)
            y = jax.nn.relu(y)
            patches2, _ = im2col(y, 3, 1, 1)
            b2, np2, r2 = patches2.shape
            fractions.append(
                _layer_sparsity(lp["conv2"]["mvm"], patches2.reshape(b2 * np2, r2), spec)
            )
            y = conv_apply(lp["conv2"]["mvm"], y, spec, 3, 1, 1, False)
            y, _ = batchnorm(lp["conv2"]["bn"], y, False)
            if entry["residual"]:
                y = y + skip
            cur = jax.nn.relu(y)
    feat = cur.mean(axis=(1, 2))
    fractions.append(_layer_sparsity(params["fc"], feat, spec))
    return fractions


# full-size zoo models the slim fractions stand in for
ZOO_ALIASES = {
    "resnet20-slim": ["resnet20", "resnet32", "resnet44"],
    "wide-resnet20-slim": ["wide_resnet20"],
    "vgg9-slim": ["vgg9", "vgg11"],
    "tiny": ["resnet20"],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--out", default="../artifacts/sparsity.json")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    if args.checkpoint and pathlib.Path(args.checkpoint).exists():
        with open(args.checkpoint, "rb") as f:
            ck = pickle.load(f)
        cfg, params = ck["cfg"], ck["params"]
    else:
        from .train import train, transfer_params
        from .model import calibrate_model

        preset = "tiny" if args.quick else "resnet20-slim"
        base = model_presets()[preset]
        steps = 40 if args.quick else 250
        fp = train(dataclasses.replace(
            base, quant=dataclasses.replace(base.quant, mode="fp")),
            steps=steps, verbose=False)
        cfg = dataclasses.replace(
            base, quant=dataclasses.replace(base.quant, mode="ternary"))
        p0 = transfer_params(fp.params, cfg)
        (cx, _), _ = data_mod.train_test_split(64, 1, image=cfg.image)
        p0 = calibrate_model(p0, jnp.asarray(cx), cfg)
        r = train(cfg, steps=max(steps // 2, 20), lr=5e-4, verbose=False,
                  init_params=p0)
        params = r.params

    fractions = measure(params, cfg)
    print(f"{cfg.name}: per-layer zero fractions "
          f"min={min(fractions):.2f} mean={sum(fractions)/len(fractions):.2f} "
          f"max={max(fractions):.2f}")

    out = {}
    names = [cfg.name] + ZOO_ALIASES.get(cfg.name, [])
    for name in names:
        out[name] = {"layers": [round(f, 4) for f in fractions]}
    path = pathlib.Path(args.out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
