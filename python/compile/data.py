"""Deterministic synthetic image-classification dataset.

CIFAR-10/ImageNet are not available offline (DESIGN.md substitution #3);
this generator produces a class-structured dataset that exercises exactly
the same training/inference code paths: each class is a distinct mixture
of oriented gratings + blob patterns, with per-sample phase, amplitude and
noise jitter, so accuracy is meaningfully below 100 % and degrades as
quantization tightens — which is what the Table-2 experiments measure.
"""

import numpy as np


def make_dataset(n, image=16, classes=10, seed=0, noise=0.35):
    """Return (x [N,H,W,3] float32 in [0,1], y [N] int32)."""
    rng = np.random.default_rng(seed)
    h = w = image
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    yy, xx = yy / h, xx / w

    # one deterministic prototype per class
    protos = []
    prng = np.random.default_rng(1234)  # fixed: class structure is global
    for c in range(classes):
        fx, fy = prng.uniform(1.0, 4.0, 2)
        phase = prng.uniform(0, 2 * np.pi)
        cx, cy = prng.uniform(0.2, 0.8, 2)
        sigma = prng.uniform(0.08, 0.3)
        grating = np.sin(2 * np.pi * (fx * xx + fy * yy) + phase)
        blob = np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * sigma**2))
        mix = prng.uniform(0.3, 0.7)
        base = mix * grating + (1 - mix) * (2 * blob - 1)
        rgb = np.stack([base * prng.uniform(0.5, 1.0) for _ in range(3)], axis=-1)
        protos.append(rgb.astype(np.float32))
    protos = np.stack(protos)  # [classes, H, W, 3]

    y = rng.integers(0, classes, n).astype(np.int32)
    amp = rng.uniform(0.6, 1.4, (n, 1, 1, 1)).astype(np.float32)
    shift = rng.integers(-2, 3, (n, 2))
    x = protos[y] * amp
    # small random translation per sample
    for i in range(n):
        x[i] = np.roll(x[i], shift[i], axis=(0, 1))
    x = x + rng.normal(0, noise, x.shape).astype(np.float32)
    # normalise to [0, 1]
    x = (x - x.min()) / (x.max() - x.min() + 1e-9)
    return x.astype(np.float32), y


def train_test_split(n_train, n_test, image=16, classes=10, seed=0):
    x, y = make_dataset(n_train + n_test, image=image, classes=classes, seed=seed)
    return (x[:n_train], y[:n_train]), (x[n_train:], y[n_train:])
