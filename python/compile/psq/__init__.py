"""Partial-Sum Quantization (PSQ) training primitives.

Layer 2 of the stack: LSQ-style learned quantizers for weights,
activations, partial sums and — HCiM's addition (§4.1) — the scale
factors themselves.
"""

from .quant import (  # noqa: F401
    lsq_quantize,
    lsq_init_step,
    psq_binary,
    psq_ternary,
    adc_quantize,
    round_ste,
)
