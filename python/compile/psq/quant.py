"""Quantizers for PSQ quantization-aware training.

All quantizers are straight-through: forward computes the discrete value,
backward passes gradients as if the op were (scaled) identity, with LSQ's
gradient w.r.t. the step size (Esser et al., ICLR'20 — the paper's [14]).

Conventions
-----------
* ``lsq_quantize`` returns the *dequantized* (fake-quant) tensor, as used
  inside the training graph; integer codes for the AOT path are recovered
  by dividing by the step.
* ``psq_binary`` / ``psq_ternary`` quantize *partial sums* to p ∈ {−1,+1}
  / {−1,0,+1} (Eq. 1 of the paper) with a trainable threshold ``alpha``
  (per layer, §4.1) and straight-through gradients.
* ``adc_quantize`` emulates an N-bit ADC on partial sums (the baseline
  rows of Table 2).
"""

import jax
import jax.numpy as jnp


def round_ste(x):
    """Round with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _grad_scale(x, scale):
    """LSQ gradient scaling: forward identity, backward × scale."""
    return x * scale + jax.lax.stop_gradient(x - x * scale)


def lsq_init_step(x, bits, signed=True):
    """LSQ step initialisation: 2·mean|x| / sqrt(qmax)."""
    qmax = float(2 ** (bits - 1) - 1) if signed else float(2**bits - 1)
    return 2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(qmax) + 1e-9


def lsq_quantize(x, step, bits, signed=True):
    """Learned-step fake quantization (returns dequantized values).

    ``step`` is a trainable scalar (or broadcastable array). The gradient
    w.r.t. ``step`` follows LSQ; w.r.t. ``x`` it is the clipped STE.
    """
    if signed:
        qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    else:
        qmin, qmax = 0, 2**bits - 1
    # LSQ grad scale: 1/sqrt(numel·qmax)
    g = 1.0 / jnp.sqrt(jnp.maximum(x.size * qmax, 1.0))
    step = _grad_scale(step, g)
    step = jnp.maximum(step, 1e-9)
    q = jnp.clip(x / step, qmin, qmax)
    return round_ste(q) * step


def lsq_codes(x, step, bits, signed=True):
    """Integer codes for the AOT/export path (no gradient tricks)."""
    if signed:
        qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    else:
        qmin, qmax = 0, 2**bits - 1
    return jnp.clip(jnp.round(x / step), qmin, qmax).astype(jnp.int32)


def psq_binary(ps):
    """Binary PSQ code: p = +1 if ps ≥ 0 else −1, straight-through."""
    p = jnp.where(ps >= 0, 1.0, -1.0)
    return ps + jax.lax.stop_gradient(p - ps)


def psq_ternary(ps, alpha):
    """Ternary PSQ code with trainable threshold α (Eq. 1).

    Gradient w.r.t. ``ps`` is straight-through inside ±(α + margin);
    gradient w.r.t. ``alpha`` follows the boundary indicator (as in
    learned-threshold ternary networks).
    """
    alpha = jnp.maximum(alpha, 1e-6)
    p = jnp.where(ps >= alpha, 1.0, jnp.where(ps <= -alpha, -1.0, 0.0))
    # straight-through for ps; alpha gets a soft gradient via the gap
    soft = jnp.clip(ps / alpha, -1.0, 1.0)
    return soft + jax.lax.stop_gradient(p - soft)


def adc_quantize(ps, bits, full_scale):
    """Uniform N-bit 'ADC' on partial sums over [−fs, fs], STE."""
    levels = 2**bits - 1
    step = (2.0 * full_scale) / levels
    q = jnp.clip(jnp.round((ps + full_scale) / step), 0, levels)
    deq = q * step - full_scale
    return ps + jax.lax.stop_gradient(deq - ps)
