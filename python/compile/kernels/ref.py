"""Pure-jnp oracle for the PSQ crossbar MVM.

This is the bit-exact reference the Pallas kernel (and, transitively, the
rust gate-level DCiM model) must match. Semantics — one crossbar tile,
weight-stationary, bit-slice = bit-stream = 1:

for each input bit-plane j (x_bits) and weight bit-slice column group:
    raw[c]  = popcount-dot of (weight bits, input bits)      # analog column
    p[c]    = binary/ternary comparator vs (theta, alpha)     # Eq. 1
    PS[c]  += p[c] * scales[j, c]                             # DCiM word-op

The 2^j input shift is merged into the trained ``scales`` (paper §4.2);
the w_bits physical columns of one logical output are combined by a plain
adder downstream (``combine_slices``).
"""

import jax.numpy as jnp


def weight_bitplanes(w_codes, w_bits):
    """Two's-complement bit-planes of signed weight codes.

    Returns uint arrays ``[w_bits, R, C]`` with plane i = bit i.
    """
    pattern = jnp.asarray(w_codes, jnp.int32) & ((1 << w_bits) - 1)
    return jnp.stack([(pattern >> i) & 1 for i in range(w_bits)], axis=0)


def input_bitplanes(x_codes, x_bits):
    """Bit-planes of unsigned activation codes: ``[x_bits, ..., R]``."""
    x = jnp.asarray(x_codes, jnp.int32)
    return jnp.stack([(x >> j) & 1 for j in range(x_bits)], axis=0)


def comparator(raw, theta, alpha, ternary):
    """Eq. 1: the comparator bank (no gradients — inference reference)."""
    centered = raw - theta
    if ternary:
        return jnp.where(
            centered >= alpha, 1, jnp.where(centered <= -alpha, -1, 0)
        ).astype(jnp.int32)
    return jnp.where(centered >= 0, 1, -1).astype(jnp.int32)


def psq_mvm_ref(x, w_codes, scales, theta, alpha, *, w_bits, x_bits, ternary=True,
                ps_bits=None):
    """Reference PSQ MVM over one crossbar tile.

    Args:
      x: ``[B, R]`` unsigned activation codes (int).
      w_codes: ``[R, C]`` signed weight codes.
      scales: ``[x_bits, C * w_bits]`` integer scale-factor codes.
      theta: comparator reference (scalar).
      alpha: ternary threshold (scalar; ignored for binary).
      w_bits / x_bits: precisions (bit-slice = bit-stream = 1).
      ternary: PSQ mode.
      ps_bits: if set, wrap the accumulator to this two's-complement width
        (matching the DCiM partial-sum register).

    Returns:
      ``ps``: ``[B, C * w_bits]`` accumulated partial sums,
      ``p``: ``[x_bits, B, C * w_bits]`` comparator codes (for sparsity).
    """
    x = jnp.asarray(x, jnp.int32)
    w_planes = weight_bitplanes(w_codes, w_bits)       # [w_bits, R, C]
    # physical columns: logical col c expands to w_bits adjacent columns
    r, c = w_codes.shape
    phys = jnp.transpose(w_planes, (1, 2, 0)).reshape(r, c * w_bits)
    xp = input_bitplanes(x, x_bits)                    # [x_bits, B, R]

    thetas = theta if hasattr(theta, "__len__") else [theta] * x_bits
    ps = jnp.zeros((x.shape[0], c * w_bits), jnp.int32)
    p_all = []
    for j in range(x_bits):
        raw = xp[j].astype(jnp.int32) @ phys.astype(jnp.int32)   # [B, phys]
        p = comparator(raw, thetas[j], alpha, ternary)
        p_all.append(p)
        ps = ps + p * scales[j][None, :].astype(jnp.int32)
    if ps_bits is not None:
        m = 1 << ps_bits
        ps = ((ps % m) + m) % m
        ps = jnp.where(ps >= m // 2, ps - m, ps)
    return ps, jnp.stack(p_all, axis=0)


def combine_slices(ps, w_bits):
    """Fold the w_bits physical columns of each logical output (plain add;
    shifts/signs live in the trained scale factors)."""
    b, phys = ps.shape
    return ps.reshape(b, phys // w_bits, w_bits).sum(axis=2)


def dense_int_mvm(x, w_codes):
    """Exact integer MVM ground truth (no PSQ)."""
    return jnp.asarray(x, jnp.int32) @ jnp.asarray(w_codes, jnp.int32)
