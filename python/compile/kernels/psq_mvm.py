"""Layer-1 Pallas kernel: the PSQ crossbar MVM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's 65 nm
mixed-signal macro becomes a TPU-style tiled kernel — each grid step owns
one *crossbar tile* of the weight bit-plane matrix resident in VMEM
(BlockSpec), and streams the activation bit-planes through it, mirroring
the weight-stationary schedule of the silicon. The popcount column sums,
comparator, and scale-factor accumulation all happen in-tile, so the HLO
the AOT path emits keeps the same locality structure the accelerator has.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; correctness is checked against ``ref.psq_mvm_ref`` by the
pytest/hypothesis suite, and TPU-perf structure (VMEM footprint, tile
shapes) is analysed statically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Crossbar geometry of HCiM config A: 128 wordlines × 128 bitlines.
TILE_ROWS = 128
TILE_COLS = 128


def _psq_kernel(x_ref, w_ref, s_ref, o_ref, *, x_bits, theta, alpha, ternary):
    """One grid step: a [B, R_tile] × [R_tile, C_tile] PSQ tile-MVM.

    x_ref: [B, R_tile] int32 activation codes (unsigned values).
    w_ref: [R_tile, C_tile] int32 weight *bits* (0/1 — pre-sliced planes).
    s_ref: [x_bits, C_tile] int32 scale-factor codes.
    o_ref: [B, C_tile] int32 partial-sum accumulator for this tile.
    """
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    thetas = theta if isinstance(theta, (tuple, list)) else (theta,) * x_bits
    for j in range(x_bits):  # static unroll: one analog bit-stream per step
        xb = ((x >> j) & 1).astype(jnp.float32)
        # idealised analog column: popcount dot of the bit-planes.
        raw = jnp.dot(xb, w)  # [B, C_tile]
        centered = raw - thetas[j]
        if ternary:
            p = jnp.where(
                centered >= alpha,
                1,
                jnp.where(centered <= -alpha, -1, 0),
            ).astype(jnp.int32)
        else:
            p = jnp.where(centered >= 0, 1, -1).astype(jnp.int32)
        acc = acc + p * s_ref[j][None, :]
    o_ref[...] = acc


def psq_mvm_pallas(x, w_bits_planes, scales, *, x_bits, theta, alpha,
                   ternary=True, interpret=True):
    """PSQ MVM over pre-bit-sliced weights, tiled like the crossbar array.

    Args:
      x: ``[B, R]`` int32 unsigned activation codes.
      w_bits_planes: ``[R, P]`` int32 0/1 weight bits (P physical columns,
        logical col c at columns ``c*w_bits .. (c+1)*w_bits``).
      scales: ``[x_bits, P]`` int32 scale-factor codes.
      theta: comparator reference — a scalar, or a tuple of ``x_bits``
        per-stream references (the comparator DAC can step per cycle).

    Returns ``[B, P]`` int32 partial sums (Σ_j p·s, shifts merged in s).

    The grid walks (row tiles × column tiles); row tiles accumulate —
    matching how multiple crossbars' partial sums combine digitally in the
    chip (the inter-crossbar accumulation of §5.3's config-B discussion).
    """
    b, r = x.shape
    r2, p = w_bits_planes.shape
    assert r == r2, f"row mismatch {r} vs {r2}"
    assert scales.shape == (x_bits, p), f"scales shape {scales.shape}"

    row_tiles = -(-r // TILE_ROWS)
    col_tiles = -(-p // TILE_COLS)

    # pad to tile multiples (idle wordlines/bitlines in the silicon)
    rp = row_tiles * TILE_ROWS
    cp = col_tiles * TILE_COLS
    x_pad = jnp.pad(x, ((0, 0), (0, rp - r)))
    w_pad = jnp.pad(w_bits_planes, ((0, rp - r), (0, cp - p)))
    s_pad = jnp.pad(scales, ((0, 0), (0, cp - p)))

    kernel = functools.partial(
        _psq_kernel, x_bits=x_bits, theta=theta, alpha=alpha, ternary=ternary
    )

    out = jnp.zeros((b, cp), jnp.int32)
    # one pallas_call per row tile; partial sums accumulate across tiles
    for rt in range(row_tiles):
        tile_out = pl.pallas_call(
            kernel,
            grid=(col_tiles,),
            in_specs=[
                pl.BlockSpec((b, TILE_ROWS), lambda c: (0, 0)),
                pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda c: (0, c)),
                pl.BlockSpec((x_bits, TILE_COLS), lambda c: (0, c)),
            ],
            out_specs=pl.BlockSpec((b, TILE_COLS), lambda c: (0, c)),
            out_shape=jax.ShapeDtypeStruct((b, cp), jnp.int32),
            interpret=interpret,
        )(
            x_pad[:, rt * TILE_ROWS : (rt + 1) * TILE_ROWS],
            w_pad[rt * TILE_ROWS : (rt + 1) * TILE_ROWS],
            s_pad,
        )
        out = out + tile_out
    return out[:, :p]
