"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO)."""

from .psq_mvm import psq_mvm_pallas  # noqa: F401
from . import ref  # noqa: F401
