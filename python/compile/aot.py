"""AOT compilation: trained PSQ model → HLO text artifacts for the rust
runtime (Layer 2 → Layer 3 hand-off).

The inference graph is rebuilt around the *Pallas kernel*
(`kernels.psq_mvm.psq_mvm_pallas`, interpret=True) so the lowered HLO
contains the L1 kernel's structure; BN/ReLU/pooling are plain jnp around
it. Lowering goes through **HLO text** — NOT `.serialize()` — because the
image's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit instruction-id
protos (see /opt/xla-example/README.md); the text parser reassigns ids.

Outputs under `artifacts/`:
  model_b{B}.hlo.txt   one executable per exported batch size
  manifest.json        input/output shapes, quant spec, accuracy, files

Usage:
  python -m compile.aot --out-dir ../artifacts [--checkpoint ckpt.pkl]
                        [--batches 1,8] [--quick]
"""

import argparse
import dataclasses
import json
import pathlib
import pickle

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.psq_mvm import psq_mvm_pallas
from .model import ModelCfg, batchnorm, im2col, model_presets, model_structure
from .psq.quant import lsq_codes


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # big weight tensors as "...", which the consuming HLO text parser
    # silently turns into zeros/garbage.
    return comp.as_hlo_text(print_large_constants=True)


# ---------------------------------------------------------------------------
# inference graph around the pallas kernel
# ---------------------------------------------------------------------------


def _freeze_mvm(p, spec):
    """Pre-compute the static (numpy) view of one MVM layer: integer codes,
    bit-planes, per-group comparator constants. Done OUTSIDE the trace so
    the lowered HLO embeds them as constants."""
    x_step = float(np.exp(p["x_step_log"]))
    w_step = float(np.exp(p["w_step_log"]))
    wc = np.asarray(lsq_codes(p["w"], w_step, spec.w_bits, signed=True))
    frozen = {
        "x_step": x_step,
        "w_step": w_step,
        "out_step": float(p["out_step"]),
        "wc": wc,
    }
    if not spec.is_psq:
        return frozen
    r, c = wc.shape
    g = spec.xbar_rows
    groups = max(1, -(-r // g))
    sf_step = float(np.exp(p["sf_step_log"]))
    frozen.update(sf_step=sf_step, groups=[], bias=np.asarray(p["bias"]))
    for gi in range(groups):
        sl = slice(gi * g, min((gi + 1) * g, r))
        wg = wc[sl] & ((1 << spec.w_bits) - 1)
        phys = np.stack(
            [(wg >> i) & 1 for i in range(spec.w_bits)], axis=-1
        ).reshape(wg.shape[0], c * spec.w_bits).astype(np.int32)
        s = np.asarray(p["scales"][gi])
        if spec.sf_share > 1:
            s = np.repeat(s, spec.sf_share, axis=1)[:, : c * spec.w_bits]
        s_codes = np.asarray(lsq_codes(jnp.asarray(s), sf_step, spec.sf_bits,
                                       signed=True))
        frozen["groups"].append(
            {
                "slice": (sl.start, sl.stop),
                "phys": phys,
                "s_codes": s_codes,
                "theta": tuple(float(t) for t in np.asarray(p["theta"][gi])),
                "alpha": float(p["alpha"][gi]),
            }
        )
    return frozen


def _mvm_infer(frozen, x2d, spec):
    """Inference-time MVM: integer codes through the L1 kernel."""
    xc_ = jnp.clip(
        jnp.round(jnp.maximum(x2d, 0.0) / frozen["x_step"]), 0, 2**spec.x_bits - 1
    ).astype(jnp.int32)
    scale = frozen["x_step"] * frozen["w_step"] * frozen["out_step"]

    if not spec.is_psq:
        out = xc_.astype(jnp.float32) @ frozen["wc"].astype(np.float32)
        return out * scale

    c = frozen["wc"].shape[1]
    ternary = spec.mode == "ternary"
    acc = jnp.zeros((x2d.shape[0], c * spec.w_bits), jnp.float32)
    for grp in frozen["groups"]:
        lo, hi = grp["slice"]
        ps = psq_mvm_pallas(
            xc_[:, lo:hi],
            jnp.asarray(grp["phys"]),
            jnp.asarray(grp["s_codes"]),
            x_bits=spec.x_bits,
            theta=grp["theta"],
            alpha=grp["alpha"],
            ternary=ternary,
        )
        acc = acc + ps.astype(jnp.float32) * frozen["sf_step"]
    out = acc.reshape(x2d.shape[0], c, spec.w_bits).sum(axis=2) + frozen["bias"][None, :]
    return out * scale


def build_infer_fn(params, cfg: ModelCfg):
    """The full inference function x[B,H,W,3] → logits[B,classes]."""
    spec = cfg.quant
    plan, _ = model_structure(cfg)

    # freeze every MVM layer's static view up front
    frozen_layers = []
    for entry, lp in zip(plan, params["layers"]):
        if entry["kind"] == "conv":
            frozen_layers.append({"mvm": _freeze_mvm(lp["mvm"], spec), "bn": lp["bn"]})
        else:
            frozen_layers.append(
                {
                    "conv1": {"mvm": _freeze_mvm(lp["conv1"]["mvm"], spec),
                              "bn": lp["conv1"]["bn"]},
                    "conv2": {"mvm": _freeze_mvm(lp["conv2"]["mvm"], spec),
                              "bn": lp["conv2"]["bn"]},
                }
            )
    frozen_fc = _freeze_mvm(params["fc"], spec)

    def infer(x):
        cur = x
        for entry, lp in zip(plan, frozen_layers):
            if entry["kind"] == "conv":
                k = entry["k"]
                patches, (oh, ow) = im2col(cur, k, entry["stride"], k // 2)
                b, np_, r = patches.shape
                y = _mvm_infer(lp["mvm"], patches.reshape(b * np_, r), spec)
                y = y.reshape(b, oh, ow, -1)
                y, _ = batchnorm(lp["bn"], y, train=False)
                cur = jax.nn.relu(y)
                if entry["pool"]:
                    cur = cur[:, ::2, ::2, :]
            else:
                skip = cur
                patches, (oh, ow) = im2col(cur, 3, entry["stride"], 1)
                b, np_, r = patches.shape
                y = _mvm_infer(lp["conv1"]["mvm"], patches.reshape(b * np_, r), spec)
                y = y.reshape(b, oh, ow, -1)
                y, _ = batchnorm(lp["conv1"]["bn"], y, train=False)
                y = jax.nn.relu(y)
                patches, (oh2, ow2) = im2col(y, 3, 1, 1)
                b, np_, r = patches.shape
                y = _mvm_infer(lp["conv2"]["mvm"], patches.reshape(b * np_, r), spec)
                y = y.reshape(b, oh2, ow2, -1)
                y, _ = batchnorm(lp["conv2"]["bn"], y, train=False)
                if entry["residual"]:
                    y = y + skip
                cur = jax.nn.relu(y)
        feat = cur.mean(axis=(1, 2))
        return (_mvm_infer(frozen_fc, feat, spec),)

    return infer


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------


def export(checkpoint=None, out_dir="../artifacts", batches=(1, 8), quick=False):
    out_dir = pathlib.Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    if checkpoint and pathlib.Path(checkpoint).exists():
        with open(checkpoint, "rb") as f:
            ck = pickle.load(f)
        cfg, params, acc = ck["cfg"], ck["params"], ck.get("test_acc", float("nan"))
        print(f"loaded checkpoint {checkpoint} (acc {acc:.3f})")
    else:
        # no checkpoint: train a small model on the spot (quick QAT)
        from .train import train, transfer_params
        from .model import calibrate_model
        from . import data as data_mod

        preset = "tiny" if quick else "resnet20-slim"
        base = model_presets()[preset]
        steps = 40 if quick else 250
        fp_cfg = dataclasses.replace(
            base, quant=dataclasses.replace(base.quant, mode="fp")
        )
        fp = train(fp_cfg, steps=steps, verbose=False)
        cfg = dataclasses.replace(
            base, quant=dataclasses.replace(base.quant, mode="ternary")
        )
        p0 = transfer_params(fp.params, cfg)
        (cx, _), _ = data_mod.train_test_split(64, 1, image=cfg.image)
        p0 = calibrate_model(p0, jnp.asarray(cx), cfg)
        r = train(cfg, steps=max(steps // 2, 20), lr=5e-4, verbose=False,
                  init_params=p0)
        params, acc = r.params, r.test_acc
        print(f"trained {cfg.name}/ternary on the fly (acc {acc:.3f})")

    infer = build_infer_fn(params, cfg)
    files = {}
    for b in batches:
        spec_in = jax.ShapeDtypeStruct((b, cfg.image, cfg.image, 3), jnp.float32)
        lowered = jax.jit(infer).lower(spec_in)
        text = to_hlo_text(lowered)
        name = f"model_b{b}.hlo.txt"
        (out_dir / name).write_text(text)
        files[str(b)] = name
        print(f"wrote {out_dir / name} ({len(text)} chars)")

    # golden cross-check: logits for a deterministic linspace input, so the
    # rust runtime can verify end-to-end numerics after loading the HLO
    import numpy as _np
    n_in = cfg.image * cfg.image * 3
    gx = _np.linspace(0.0, 1.0, n_in, dtype=_np.float32).reshape(1, cfg.image, cfg.image, 3)
    (glogits,) = jax.jit(infer)(jnp.asarray(gx))
    manifest = {
        "golden_logits": [float(v) for v in _np.asarray(glogits)[0]],
        "model": cfg.name,
        "mode": cfg.quant.mode,
        "image": cfg.image,
        "classes": cfg.classes,
        "w_bits": cfg.quant.w_bits,
        "x_bits": cfg.quant.x_bits,
        "sf_bits": cfg.quant.sf_bits,
        "ps_bits": cfg.quant.ps_bits,
        "xbar_rows": cfg.quant.xbar_rows,
        "test_acc": float(acc),
        "batches": files,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_dir / 'manifest.json'}")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", default="1,8")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    batches = tuple(int(b) for b in args.batches.split(","))
    export(args.checkpoint, args.out_dir, batches, args.quick)


if __name__ == "__main__":
    main()
