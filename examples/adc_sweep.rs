//! Domain study: where does the ADC-less design win?
//!
//! Sweeps (a) baseline ADC precision, (b) ternary sparsity, (c) crossbar
//! geometry, printing the energy / latency×area landscape around the
//! paper's two operating points (configs A & B).
//!
//!   cargo run --release --example adc_sweep

use hcim::config::hardware::{BaselineKind, CrossbarDims, HcimConfig};
use hcim::experiments;
use hcim::model::zoo;
use hcim::sim::simulator::{Arch, Simulator};
use hcim::sim::tech::TechNode;
use hcim::util::table::{fnum, Table};

fn main() -> hcim::Result<()> {
    let sim = Simulator::new(TechNode::N32);
    let g = zoo::resnet20();

    // (a) ADC precision sweep (the ablation table)
    experiments::ablation_adc_precision_sweep(&sim).print();

    // (b) sparsity sweep — Fig 5(a)
    experiments::fig5a().print();

    // (c) crossbar geometry sweep: 32..256 on both HCiM and the 4-bit
    // flash baseline (extends the paper's A/B comparison to a curve)
    let mut t = Table::new(
        "Crossbar-size sweep — ResNet-20 energy (µJ) and latency×area",
        &["xbar", "HCiM E", "Flash4 E", "E ratio", "HCiM L·A", "Flash4 L·A", "L·A ratio"],
    );
    for size in [32usize, 64, 128, 256] {
        let mut cfg = HcimConfig::config_a();
        // >128 columns → multiple DCiM arrays per crossbar; the model
        // clamps one array at 128, so keep cols ≤ 128 and scale rows
        cfg.xbar = CrossbarDims { rows: size, cols: size.min(128) };
        let h = sim.run(&g, &Arch::Hcim(cfg.clone()));
        let f = sim.run(&g, &Arch::AdcBaseline(cfg.clone(), BaselineKind::AdcFlash4));
        t.row(&[
            format!("{}x{}", cfg.xbar.rows, cfg.xbar.cols),
            fnum(h.energy_pj() / 1e6),
            fnum(f.energy_pj() / 1e6),
            format!("{:.2}x", f.energy_pj() / h.energy_pj()),
            fnum(h.latency_area() / 1e9),
            fnum(f.latency_area() / 1e9),
            format!("{:.2}x", h.latency_area() / f.latency_area()),
        ]);
    }
    t.print();

    // peripheral-sharing ablation
    experiments::ablation_phase_sharing().print();
    Ok(())
}
