//! Domain study: where does the ADC-less design win?
//!
//! Sweeps (a) baseline ADC precision, (b) ternary sparsity, (c) crossbar
//! geometry, printing the energy / latency×area landscape around the
//! paper's two operating points (configs A & B). Sections (a) and (c) are
//! thin clients of the `hcim::dse` subsystem — (a) through the experiments
//! registry, (c) as a custom design space priced by the parallel runner.
//!
//!   cargo run --release --example adc_sweep

use hcim::config::hardware::CrossbarDims;
use hcim::dse::{ArchKind, DesignSpace, SweepReport, SweepRunner};
use hcim::experiments;
use hcim::sim::simulator::Simulator;
use hcim::sim::tech::TechNode;
use hcim::util::table::{fnum, Table};

fn main() -> hcim::Result<()> {
    let sim = Simulator::new(TechNode::N32);

    // (a) ADC precision sweep (the ablation table, DSE-backed)
    experiments::ablation_adc_precision_sweep(&sim).print();

    // (b) sparsity sweep — Fig 5(a)
    experiments::fig5a().print();

    // (c) crossbar geometry sweep: 32..256 on both HCiM and the 4-bit
    // flash baseline (extends the paper's A/B comparison to a curve).
    // >128 columns → multiple DCiM arrays per crossbar; the model clamps
    // one array at 128, so keep cols ≤ 128 and scale rows.
    let sizes = [
        CrossbarDims { rows: 32, cols: 32 },
        CrossbarDims { rows: 64, cols: 64 },
        CrossbarDims { rows: 128, cols: 128 },
        CrossbarDims { rows: 256, cols: 128 },
    ];
    let space = DesignSpace::new()
        .with_workloads(&["resnet20"])
        .with_sizes(&sizes)
        .with_nodes(&[TechNode::N32])
        .with_archs(&[ArchKind::HcimTernary, ArchKind::AdcFlash4]);
    let sweep = SweepRunner::new(space).run()?;

    let mut t = Table::new(
        "Crossbar-size sweep — ResNet-20 energy (µJ) and latency×area",
        &["xbar", "HCiM E", "Flash4 E", "E ratio", "HCiM L·A", "Flash4 L·A", "L·A ratio"],
    );
    for size in sizes {
        let find = |arch: ArchKind| {
            sweep
                .points
                .iter()
                .find(|p| p.point.xbar == size && p.point.arch == arch)
                .expect("point swept")
        };
        let h = &find(ArchKind::HcimTernary).metrics;
        let f = &find(ArchKind::AdcFlash4).metrics;
        t.row(&[
            format!("{}x{}", size.rows, size.cols),
            fnum(h.energy_pj / 1e6),
            fnum(f.energy_pj / 1e6),
            format!("{:.2}x", f.energy_pj / h.energy_pj),
            fnum(h.latency_area() / 1e9),
            fnum(f.latency_area() / 1e9),
            format!("{:.2}x", h.latency_area() / f.latency_area()),
        ]);
    }
    t.print();

    // the same sweep's Pareto view: which (geometry, periphery) points are
    // optimal trade-offs in (energy, latency, area)?
    SweepReport::build(&sweep).pareto_table().print();

    // peripheral-sharing ablation
    experiments::ablation_phase_sharing().print();
    Ok(())
}
