//! End-to-end serving driver (the repo's E2E validation workload):
//! loads the AOT-compiled PSQ model (trained by the python build path,
//! lowered through the Pallas kernel), serves batched classification
//! requests through the rust coordinator on the PJRT CPU client, and
//! reports latency/throughput plus the co-simulated HCiM hardware cost.
//!
//!   make artifacts            # build + train + lower (one-time)
//!   cargo run --release --example serve_cifar -- [artifacts-dir] [requests] [seed]

use std::sync::Arc;
use std::time::Duration;

use hcim::coordinator::{Server, ServerConfig};
use hcim::runtime::Engine;
use hcim::util::rng::Rng;

/// Synthetic test images mirroring `python/compile/data.py`'s value range.
/// Draws from a generator forked off the single master seed — never from
/// hand-picked sequential seeds, which correlate streams.
fn synth_images(n: usize, elems: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..elems).map(|_| rng.f64() as f32).collect())
        .collect()
}

fn main() -> hcim::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dir = args.get(1).map(|s| s.as_str()).unwrap_or("artifacts");
    let requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let seed: u64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(42);
    // one master generator; every stochastic path below forks from it
    let mut master = Rng::new(seed);

    let engine = Arc::new(Engine::load(std::path::Path::new(dir))?);
    let m = engine.manifest.clone();
    println!(
        "model={} mode={} input={}x{}x3 classes={} exported-acc={:.3} batches={:?}",
        m.model,
        m.mode,
        m.image,
        m.image,
        m.classes,
        m.test_acc,
        engine.batch_sizes()
    );

    // ---- phase 1: offline burst (throughput) ----
    println!("\n== burst: {requests} requests, dynamic batching ==");
    let mut server = Server::start(
        Arc::clone(&engine),
        ServerConfig {
            max_batch: m.max_batch(),
            batch_window: Duration::from_millis(1),
            workers: 2,
        },
    );
    if let Some(hw) = &server.hw_estimate {
        println!(
            "co-sim: {} on {} → {:.2} µJ / {:.1} µs per inference",
            hw.model,
            hw.arch,
            hw.energy_pj() / 1e6,
            hw.latency_ns() / 1e3
        );
    }
    let images = synth_images(requests, m.input_elems(), &mut master.fork());
    for img in &images {
        server.submit(img.clone());
    }
    let responses = server.collect_timeout(requests, Duration::from_secs(120))?;
    let metrics = server.shutdown();
    let mut class_hist = vec![0usize; m.classes];
    for r in &responses {
        class_hist[r.class] += 1;
    }
    println!("class histogram: {class_hist:?}");
    println!("{}", metrics.snapshot());

    // ---- phase 2: paced arrivals (latency under load) ----
    println!("\n== paced: {requests} requests at ~500 req/s ==");
    let mut server = Server::start(
        engine,
        ServerConfig {
            max_batch: m.max_batch(),
            batch_window: Duration::from_millis(2),
            workers: 2,
        },
    );
    let mut arrival_rng = master.fork();
    for img in synth_images(requests, m.input_elems(), &mut master.fork()) {
        server.submit(img);
        // exponential inter-arrival, mean 2 ms
        let gap = -2000.0 * (1.0 - arrival_rng.f64()).ln();
        std::thread::sleep(Duration::from_micros(gap as u64));
    }
    let _ = server.collect_timeout(requests, Duration::from_secs(120))?;
    let metrics = server.shutdown();
    println!("{}", metrics.snapshot());
    Ok(())
}
