//! Quickstart: program one HCiM tile (analog crossbar + comparators +
//! gate-level DCiM scale-factor array), run a bit-exact PSQ MVM, and show
//! the energy/latency breakdown next to the ADC baseline.
//!
//! No artifacts needed:  `cargo run --release --example quickstart`

use hcim::config::hardware::HcimConfig;
use hcim::quant::bits::Mat;
use hcim::quant::psq::{psq_mvm, PsqLayerParams, PsqMode};
use hcim::sim::energy::CostLedger;
use hcim::sim::params::{CalibParams, ADC_SAR7};
use hcim::sim::tile::{baseline_mvm_cost, hcim_mvm_cost, HcimTile, MvmStats};
use hcim::util::rng::Rng;

fn main() -> hcim::Result<()> {
    println!("== HCiM quickstart: one crossbar tile, bit-exact ==\n");

    // a 32×8 logical weight matrix of 4-bit codes (→ 32 physical columns)
    let mut rng = Rng::new(7);
    let cfg = {
        let mut c = HcimConfig::config_a();
        c.xbar.rows = 32;
        c.xbar.cols = 32;
        c
    };
    let w = Mat::from_fn(32, 8, |r, c| ((r * 5 + c * 11) as i64 % 15) - 7);
    let mut psq = PsqLayerParams::calibrated(
        &w,
        PsqMode::Ternary { alpha: 2.0 },
        cfg.w_bits,
        cfg.x_bits,
        cfg.ps_bits,
        &mut rng,
    );
    psq.theta = 8.0;

    // program the tile: weights into the crossbar (bit-sliced), scale
    // factors into the DCiM array (pre-loaded, like the silicon)
    let mut tile = HcimTile::program(&cfg, &w, &psq);
    let params = CalibParams::at_65nm();

    // run one MVM through crossbar → comparators → DCiM pipeline
    let x: Vec<i64> = (0..32).map(|i| (i * 7) % 16).collect();
    let mut ledger = CostLedger::new();
    let ps = tile.mvm(&x, &params, &mut ledger);

    // the integer PSQ reference must agree bit-for-bit
    let reference = psq_mvm(&w, &x, &psq);
    assert_eq!(ps, reference.ps, "gate-level DCiM == integer PSQ reference");
    println!("partial sums (first 8 physical columns): {:?}", &ps[..8]);
    println!("measured ternary sparsity: {:.1}%\n", tile.sparsity() * 100.0);
    println!("tile cost ledger:\n{ledger}");

    // headline comparison at full config-A scale
    println!("== config A, per crossbar-MVM: HCiM vs 7-bit SAR baseline ==\n");
    let full = HcimConfig::config_a();
    let stats = MvmStats { sparsity: tile.sparsity(), ..Default::default() };
    let h = hcim_mvm_cost(&full, &params, &stats);
    let b = baseline_mvm_cost(&full, &ADC_SAR7, &params, &stats);
    println!(
        "HCiM:     {:>8.1} pJ  {:>8.1} ns",
        h.total_energy_pj(),
        h.latency_ns
    );
    println!(
        "ADC-7b:   {:>8.1} pJ  {:>8.1} ns",
        b.total_energy_pj(),
        b.latency_ns
    );
    println!(
        "→ {:.1}× lower energy, {:.1}× lower latency",
        b.total_energy_pj() / h.total_energy_pj(),
        b.latency_ns / h.latency_ns
    );
    Ok(())
}
