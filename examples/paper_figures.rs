//! Regenerate every table and figure of the paper's evaluation (§5).
//!
//!   cargo run --release --example paper_figures -- [artifacts-dir]
//!
//! Accuracy artifacts (Table 2 / Fig 2(b,d)) appear once `make accuracy`
//! has produced `artifacts/accuracy.json`; the performance tables are
//! fully self-contained.

use std::path::Path;

use hcim::config::hardware::HcimConfig;
use hcim::experiments;

fn main() -> hcim::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dir = Path::new(args.get(1).map(|s| s.as_str()).unwrap_or("artifacts"));
    let sim = experiments::system_simulator(dir);

    experiments::table1().print();
    match experiments::table2(dir) {
        Some(t) => t.print(),
        None => println!(
            "(Table 2 pending — run `make accuracy` to train the sweep and \
             produce artifacts/accuracy.json)\n"
        ),
    }
    if let Some(t) = experiments::fig2d(dir) {
        t.print();
    }
    experiments::table3().print();
    experiments::fig1(&sim).table.print();
    experiments::fig2c(&sim).print();
    experiments::fig5a().print();
    experiments::fig5b(&sim).1.print();
    experiments::fig67_table(&sim, &HcimConfig::config_a(), "Fig 6 (config A)").print();
    experiments::fig67_table(&sim, &HcimConfig::config_b(), "Fig 7 (config B)").print();
    experiments::ablation_phase_sharing().print();
    experiments::ablation_adc_precision_sweep(&sim).print();

    // headline claims digest (EXPERIMENTS.md source of truth)
    let reports = experiments::headline_reports(&sim);
    let (tern, bin, sar7, flash4) = (&reports[0], &reports[1], &reports[2], &reports[3]);
    println!("== headline digest (ResNet-20, config A) ==");
    println!(
        "energy:   vs 7b SAR {:.1}×   vs 4b Flash {:.1}×   ternary saves {:.0}% over binary",
        sar7.energy_pj() / tern.energy_pj(),
        flash4.energy_pj() / tern.energy_pj(),
        100.0 * (1.0 - tern.energy_pj() / bin.energy_pj()),
    );
    println!(
        "lat×area: vs 7b SAR {:.1}×   vs 4b Flash {:.2}× (paper: HCiM slightly worse than flash)",
        sar7.latency_area() / tern.latency_area(),
        tern.latency_area() / flash4.latency_area(),
    );
    Ok(())
}
