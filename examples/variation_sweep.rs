//! Sweep the analog non-ideality magnitudes and watch the PSQ code
//! decisions degrade: conductance variation × bitline IR drop on one axis
//! pair, comparator offset on its own, plus a full robustness report at
//! the node's default magnitudes.
//!
//! No artifacts needed:
//!   cargo run --release --example variation_sweep -- [trials] [model]
//! (defaults: 16 trials, resnet20; the CI smoke run passes 4)

use hcim::config::hardware::HcimConfig;
use hcim::model::zoo;
use hcim::nonideal::{run_monte_carlo, MonteCarloCfg, NonIdealityParams};
use hcim::util::table::Table;

fn main() -> hcim::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let trials: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16).max(1);
    let model = args.get(2).map(|s| s.as_str()).unwrap_or("resnet20");
    let graph = zoo::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model `{model}`"))?;
    let cfg = HcimConfig::config_a();
    let mc = MonteCarloCfg { trials, seed: 42, workers: 0 };

    println!(
        "== variation sweep: {model}, config {}, {} trials/point ==\n",
        cfg.name, trials
    );

    // conductance sigma × IR drop grid (comparators ideal): the two array
    // effects compound because both move the analog sum the comparator sees
    let sigmas = [0.0, 0.05, 0.10, 0.20];
    let drops = [0.0, 0.05, 0.10];
    let mut grid = Table::new(
        "PSQ flip rate — conductance sigma (rows) x IR drop (cols)",
        &["sigma_G \\ ir_drop", "0.00", "0.05", "0.10"],
    );
    for &sigma in &sigmas {
        let mut cells = vec![format!("{sigma:.2}")];
        for &drop in &drops {
            let ni = NonIdealityParams {
                sigma_g: sigma,
                ir_drop: drop,
                ..NonIdealityParams::ideal()
            };
            let r = run_monte_carlo(&graph, &cfg, &ni, &mc);
            cells.push(format!("{:.5}", r.flip.mean));
        }
        grid.row(&cells);
    }
    grid.print();

    // comparator offset alone: the effect ADC-based peripheries do not have
    let mut cmp = Table::new(
        "PSQ flip rate / zero-code corruption vs comparator offset sigma (LSB)",
        &["sigma_cmp", "Flip rate", "Zero-code corruption"],
    );
    for &sigma in &[0.0, 0.25, 0.5, 1.0] {
        let ni = NonIdealityParams { sigma_cmp: sigma, ..NonIdealityParams::ideal() };
        let r = run_monte_carlo(&graph, &cfg, &ni, &mc);
        cmp.row(&[
            format!("{sigma:.2}"),
            format!("{:.5}", r.flip.mean),
            format!("{:.5}", r.zero.mean),
        ]);
    }
    cmp.print();

    // everything on at the node's default magnitudes
    let ni = NonIdealityParams::default_for(cfg.node);
    let report = run_monte_carlo(&graph, &cfg, &ni, &mc);
    report.params_table().print();
    report.table().print();
    Ok(())
}
