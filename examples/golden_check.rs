//! Numeric cross-check: execute the AOT artifacts on the canonical
//! linspace input and compare against the python-side golden logits
//! embedded in the manifest (the guard that caught the HLO-text
//! constant-elision bug).
//!
//!   cargo run --release --example golden_check -- [artifacts-dir]

fn main() -> hcim::Result<()> {
    anyhow::ensure!(
        cfg!(feature = "pjrt"),
        "golden_check needs real PJRT execution — rebuild with --features pjrt \
         (the default offline build serves synthetic logits)"
    );
    let args: Vec<String> = std::env::args().collect();
    let dir = args.get(1).map(|s| s.as_str()).unwrap_or("artifacts");
    let engine = hcim::runtime::Engine::load(std::path::Path::new(dir))?;
    let m = &engine.manifest;
    anyhow::ensure!(
        !m.golden_logits.is_empty(),
        "manifest has no golden logits — re-run `make artifacts`"
    );
    let n = m.input_elems();
    let img: Vec<f32> = (0..n).map(|i| i as f32 / (n - 1) as f32).collect();
    let logits = engine.infer(&img, 1)?;
    let mut worst = 0f64;
    for (got, want) in logits[0].iter().zip(&m.golden_logits) {
        worst = worst.max((*got as f64 - want).abs());
    }
    println!("rust logits:   {:?}", &logits[0][..logits[0].len().min(5)]);
    println!(
        "python golden: {:?}",
        &m.golden_logits[..m.golden_logits.len().min(5)]
    );
    println!("max |Δ| = {worst:.3e}");
    anyhow::ensure!(worst < 1e-3, "numeric mismatch across the AOT boundary");
    println!("golden check OK — L1/L2 python == L3 rust PJRT");
    Ok(())
}
